//! The SiDA serving pipeline (paper Fig 5 + Algorithm 1).
//!
//! Three OS threads realize the paper's design:
//!
//! ```text
//! hash-building thread   runs the hash artifact on batch X_j, pushes
//!                        H_j onto the bounded hash-table queue
//! prefetch stage         pops (X_i, H_i), loads the predicted experts
//!                        into the device cache ahead of compute — the
//!                        paper folds this into the inference thread's
//!                        "dynamical loading right after the finish of
//!                        inference on the previous batch" (pipeline
//!                        parallelism); a dedicated stage realizes the
//!                        same overlap explicitly
//! inference thread       forwards X_i with the hash table replacing
//!                        every router (routers never execute)
//! ```
//!
//! The inference thread "never idles except at the very beginning"
//! (paper §3.1) because a hash build + prefetch is faster than a forward
//! pass; the bounded queue provides the backpressure that keeps the
//! pipeline stable.
//!
//! With `PipelineConfig::max_batch > 1` the middle stage becomes a
//! batch former + batch-union prefetcher: consecutive requests are
//! coalesced, the union of their predicted expert sets is warmed once
//! per batch, and the inference thread serves each batch with a single
//! cross-request `forward_batch` — one expert invocation per activated
//! expert per batch, bit-identical outputs to batch-1 serving.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::hash_table::HashTable;
use crate::coordinator::hash_thread::HashBuilder;
use crate::experts::{make_policy, plan_prefetch_union, ExpertCache, ExpertKey};
use crate::memory::CostModel;
use crate::metrics::ServeStats;
use crate::model::{BatchItem, ExpertProvider, ForwardOptions, ModelRunner};
use crate::runtime::ModelBundle;
use crate::workload::Request;

#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// experts consumed per token from the hash table (paper §4: top-1
    /// for SST2, top-3 for MRPC/MultiRC)
    pub k_used: usize,
    /// simulated device budget in bytes for expert weights
    pub budget_sim_bytes: usize,
    /// eviction policy name (paper default: fifo)
    pub policy: String,
    /// sleep modeled transfer time on the critical path
    pub real_sleep: bool,
    /// run the prefetch stage (false = fetch on demand at compute time,
    /// an ablation that shows what the look-ahead buys)
    pub prefetch: bool,
    /// hash-table queue depth
    pub queue_depth: usize,
    /// requests coalesced per forward pass (1 = the paper's batch-1
    /// setting; > 1 enables cross-request batching: one expert
    /// invocation per activated expert per batch, batch-union prefetch)
    pub max_batch: usize,
    pub want_lm: bool,
    pub want_cls: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            k_used: 1,
            budget_sim_bytes: 8 << 30,
            policy: "fifo".into(),
            real_sleep: false,
            prefetch: true,
            queue_depth: 8,
            max_batch: 1,
            want_lm: false,
            want_cls: false,
        }
    }
}

/// Result of serving one trace through the pipeline.
pub struct ServeOutcome {
    pub stats: ServeStats,
    /// per-request (id, latency, cls_argmax, lm_nll-sum, token count)
    pub per_request: Vec<RequestResult>,
}

#[derive(Debug, Clone)]
pub struct RequestResult {
    pub id: u64,
    pub latency_secs: f64,
    pub cls_pred: Option<usize>,
    pub lm_nll: Option<f64>,
    pub lm_tokens: Option<f64>,
    pub n_tokens: usize,
}

/// The SiDA serving pipeline: hash-building thread, optional prefetch
/// stage, inference thread — with batch-1 (`serve`, paper setting) and
/// cross-request batched (`max_batch > 1`) modes.
///
/// ```
/// use sida_moe::coordinator::{Pipeline, PipelineConfig};
///
/// let bundle = sida_moe::testkit::tiny_bundle();
/// let requests = sida_moe::testkit::tiny_trace(&bundle, 3, 0);
/// let pipeline =
///     Pipeline::new(bundle, sida_moe::testkit::TINY_PROFILE, PipelineConfig::default()).unwrap();
/// let outcome = pipeline.serve(&requests).unwrap();
/// assert_eq!(outcome.stats.requests, 3);
/// assert_eq!(outcome.stats.blocking_misses, 0); // prefetch kept the critical path clean
/// ```
pub struct Pipeline {
    pub bundle: Arc<ModelBundle>,
    pub runner: Arc<ModelRunner>,
    pub cache: Arc<Mutex<ExpertCache>>,
    pub cfg: PipelineConfig,
    pub profile: String,
}

impl Pipeline {
    pub fn new(bundle: Arc<ModelBundle>, profile: &str, cfg: PipelineConfig) -> Result<Self> {
        let runner = Arc::new(ModelRunner::new(bundle.clone(), profile)?);
        let real_expert_bytes = bundle.weights.expert_bytes(bundle.topology.moe_blocks[0], 0)?;
        let cost = CostModel::paper_scale(real_expert_bytes).with_real_sleep(cfg.real_sleep);
        let cache = Arc::new(Mutex::new(ExpertCache::new(
            cfg.budget_sim_bytes,
            cost,
            make_policy(&cfg.policy)?,
        )));
        Ok(Pipeline {
            bundle,
            runner,
            cache,
            cfg,
            profile: profile.to_string(),
        })
    }

    /// Serve a closed-loop trace; returns aggregate + per-request stats.
    ///
    /// With `cfg.max_batch > 1` this runs the cross-request batched
    /// path ([`Pipeline::serve_batched`]); the default is the paper's
    /// batch-1 pipeline.
    pub fn serve(&self, requests: &[Request]) -> Result<ServeOutcome> {
        if self.cfg.max_batch > 1 {
            return self.serve_batched(requests);
        }
        let builder = HashBuilder::new(&self.bundle, &self.profile)?;
        let (tx, rx): (
            SyncSender<(Request, HashTable)>,
            Receiver<(Request, HashTable)>,
        ) = sync_channel(self.cfg.queue_depth);

        let reqs = requests.to_vec();
        let t_start = Instant::now();

        // ---- hash-building thread -------------------------------------
        let hash_handle = std::thread::Builder::new()
            .name("sida-hash".into())
            .spawn(move || -> Result<f64> {
                let mut total_build = 0.0;
                for req in reqs {
                    let table = builder.build(req.id, &req.ids)?;
                    total_build += table.build_secs;
                    if tx.send((req, table)).is_err() {
                        break; // inference side hung up
                    }
                }
                Ok(total_build)
            })
            .expect("spawn hash thread");

        // ---- prefetch stage (optional) --------------------------------
        // The prefetcher sits between the hash queue and the inference
        // queue, warming the cache for batch i+1 while batch i computes.
        let (ptx, prx): (
            SyncSender<(Request, HashTable)>,
            Receiver<(Request, HashTable)>,
        ) = sync_channel(self.cfg.queue_depth);
        let prefetch_handle = if self.cfg.prefetch {
            let cache = self.cache.clone();
            let bundle = self.bundle.clone();
            let k_used = self.cfg.k_used;
            let moe_blocks = self.bundle.topology.moe_blocks.clone();
            Some(
                std::thread::Builder::new()
                    .name("sida-prefetch".into())
                    .spawn(move || -> Result<()> {
                        while let Ok((req, table)) = rx.recv() {
                            let mask = req.mask();
                            for (layer, &block) in moe_blocks.iter().enumerate() {
                                for expert in table.predicted_experts(layer, k_used, &mask) {
                                    let key = ExpertKey::new(block, expert);
                                    let real =
                                        bundle.weights.expert_bytes(block, expert)?;
                                    let engine = bundle.engine.clone();
                                    let weights = bundle.weights.clone();
                                    let mut guard = cache.lock().unwrap();
                                    // non-blocking: prefetch misses do not
                                    // stall the inference thread
                                    let _ = guard.ensure(key, real, false, || {
                                        crate::runtime::stage_expert_parts(
                                            &engine, &weights, block, expert,
                                        )
                                    })?;
                                }
                            }
                            if ptx.send((req, table)).is_err() {
                                break;
                            }
                        }
                        Ok(())
                    })
                    .expect("spawn prefetch thread"),
            )
        } else {
            // pass-through
            let rx_moved = rx;
            Some(
                std::thread::Builder::new()
                    .name("sida-passthrough".into())
                    .spawn(move || -> Result<()> {
                        while let Ok(item) = rx_moved.recv() {
                            if ptx.send(item).is_err() {
                                break;
                            }
                        }
                        Ok(())
                    })
                    .expect("spawn passthrough thread"),
            )
        };

        // ---- inference thread (this thread) ----------------------------
        let mut stats = ServeStats::default();
        let mut per_request = Vec::new();
        let opts = ForwardOptions {
            invoke_all: false,
            fixed_bucket: false,
            want_lm: self.cfg.want_lm,
            want_cls: self.cfg.want_cls,
        };
        while let Ok((req, table)) = prx.recv() {
            let t0 = Instant::now();
            let mut provider = ExpertProvider::Shared {
                cache: &self.cache,
                blocking: true,
            };
            let out = self.runner.forward(
                &req.ids,
                Some((&table, self.cfg.k_used)),
                &mut provider,
                opts,
            )?;
            let latency = t0.elapsed().as_secs_f64();
            stats.latency.record(latency);
            stats.phases.add(&out.times);
            stats.requests += 1;
            stats.hash_build_secs += table.build_secs;

            let cls_pred = out.cls_logits.as_ref().map(|v| argmax(v));
            let (lm_nll, lm_tokens) = match (&out.lm_logits, self.cfg.want_lm) {
                (Some(logits), true) => {
                    let (nll, cnt) = self.runner.lm_nll(logits, &req.ids)?;
                    (Some(nll), Some(cnt))
                }
                _ => (None, None),
            };
            per_request.push(RequestResult {
                id: req.id,
                latency_secs: latency,
                cls_pred,
                lm_nll,
                lm_tokens,
                n_tokens: req.n_tokens,
            });
        }
        stats.wall_secs = t_start.elapsed().as_secs_f64();
        stats.batches = stats.requests; // batch-1: one forward per request

        if let Some(h) = prefetch_handle {
            h.join().expect("prefetch thread panicked")?;
        }
        let _hash_secs = hash_handle.join().expect("hash thread panicked")?;

        self.collect_cache_stats(&mut stats);
        Ok(ServeOutcome { stats, per_request })
    }

    /// Serve a closed-loop trace with cross-request batching: the hash
    /// thread builds tables per sentence as usual, a forming stage
    /// coalesces up to `cfg.max_batch` consecutive requests and warms
    /// the cache with the **batch-union** expert set (each expert
    /// fetched at most once per batch), and the inference thread issues
    /// one [`ModelRunner::forward_batch`] per formed batch — one expert
    /// invocation per activated expert per batch.
    ///
    /// Per-request latency is the shared forward time of the batch the
    /// request rode in (all requests of a batch complete together).
    pub fn serve_batched(&self, requests: &[Request]) -> Result<ServeOutcome> {
        let builder = HashBuilder::new(&self.bundle, &self.profile)?;
        let (tx, rx): (
            SyncSender<(Request, HashTable)>,
            Receiver<(Request, HashTable)>,
        ) = sync_channel(self.cfg.queue_depth);

        let reqs = requests.to_vec();
        let t_start = Instant::now();

        // ---- hash-building thread (unchanged from batch-1) ------------
        let hash_handle = std::thread::Builder::new()
            .name("sida-hash".into())
            .spawn(move || -> Result<f64> {
                let mut total_build = 0.0;
                for req in reqs {
                    let table = builder.build(req.id, &req.ids)?;
                    total_build += table.build_secs;
                    if tx.send((req, table)).is_err() {
                        break; // inference side hung up
                    }
                }
                Ok(total_build)
            })
            .expect("spawn hash thread");

        // ---- batch former + batch-union prefetch stage ----------------
        let (ptx, prx): (
            SyncSender<Vec<(Request, HashTable)>>,
            Receiver<Vec<(Request, HashTable)>>,
        ) = sync_channel(self.cfg.queue_depth);
        let former_handle = {
            let cache = self.cache.clone();
            let bundle = self.bundle.clone();
            let k_used = self.cfg.k_used;
            let max_batch = self.cfg.max_batch.max(1);
            let prefetch = self.cfg.prefetch;
            let moe_blocks = self.bundle.topology.moe_blocks.clone();
            std::thread::Builder::new()
                .name("sida-batch-former".into())
                .spawn(move || -> Result<()> {
                    let mut pending: Vec<(Request, HashTable)> = Vec::new();
                    loop {
                        match rx.recv() {
                            Ok(item) => {
                                pending.push(item);
                                if pending.len() >= max_batch {
                                    let batch = std::mem::take(&mut pending);
                                    if prefetch {
                                        warm_batch_union(
                                            &bundle, &cache, &batch, &moe_blocks, k_used,
                                        )?;
                                    }
                                    if ptx.send(batch).is_err() {
                                        return Ok(());
                                    }
                                }
                            }
                            Err(_) => break, // hash thread done
                        }
                    }
                    if !pending.is_empty() {
                        if prefetch {
                            warm_batch_union(&bundle, &cache, &pending, &moe_blocks, k_used)?;
                        }
                        let _ = ptx.send(pending);
                    }
                    Ok(())
                })
                .expect("spawn batch-former thread")
        };

        // ---- inference thread (this thread) ----------------------------
        let mut stats = ServeStats::default();
        let mut per_request = Vec::new();
        let opts = ForwardOptions {
            invoke_all: false,
            fixed_bucket: false,
            want_lm: self.cfg.want_lm,
            want_cls: self.cfg.want_cls,
        };
        while let Ok(batch) = prx.recv() {
            let t0 = Instant::now();
            let items: Vec<BatchItem<'_>> = batch
                .iter()
                .map(|(req, table)| BatchItem {
                    ids: &req.ids[..],
                    hash: Some((table, self.cfg.k_used)),
                })
                .collect();
            let mut provider = ExpertProvider::Shared {
                cache: &self.cache,
                blocking: true,
            };
            let out = self.runner.forward_batch(&items, &mut provider, opts)?;
            let secs = t0.elapsed().as_secs_f64();
            stats.batches += 1;
            stats.phases.add(&out.times);
            for ((req, table), fo) in batch.iter().zip(out.outputs.iter()) {
                stats.latency.record(secs);
                stats.requests += 1;
                stats.hash_build_secs += table.build_secs;
                let cls_pred = fo.cls_logits.as_ref().map(|v| argmax(v));
                let (lm_nll, lm_tokens) = match (&fo.lm_logits, self.cfg.want_lm) {
                    (Some(logits), true) => {
                        let (nll, cnt) = self.runner.lm_nll(logits, &req.ids)?;
                        (Some(nll), Some(cnt))
                    }
                    _ => (None, None),
                };
                per_request.push(RequestResult {
                    id: req.id,
                    latency_secs: secs,
                    cls_pred,
                    lm_nll,
                    lm_tokens,
                    n_tokens: req.n_tokens,
                });
            }
        }
        stats.wall_secs = t_start.elapsed().as_secs_f64();

        former_handle.join().expect("batch-former thread panicked")?;
        let _hash_secs = hash_handle.join().expect("hash thread panicked")?;

        self.collect_cache_stats(&mut stats);
        Ok(ServeOutcome { stats, per_request })
    }

    fn collect_cache_stats(&self, stats: &mut ServeStats) {
        let cache = self.cache.lock().unwrap();
        let cs = cache.stats();
        stats.cache_hits = cs.hits;
        stats.cache_misses = cs.misses;
        stats.blocking_misses = cs.blocking_misses;
        stats.evictions = cs.evictions;
        stats.transferred_bytes = cs.transferred_sim_bytes;
        stats.peak_device_bytes = cache.peak();
        stats.budget_bytes = cache.budget();
    }
}

/// Warm the cache with the batch-union expert set: every expert any
/// request of the batch is predicted to activate, planned via
/// [`plan_prefetch_union`] and fetched (non-blocking) at most once.
fn warm_batch_union(
    bundle: &ModelBundle,
    cache: &Mutex<ExpertCache>,
    batch: &[(Request, HashTable)],
    moe_blocks: &[usize],
    k_used: usize,
) -> Result<()> {
    let masks: Vec<Vec<f32>> = batch.iter().map(|(req, _)| req.mask()).collect();
    let pairs: Vec<(&HashTable, &[f32])> = batch
        .iter()
        .zip(masks.iter())
        .map(|((_, table), mask)| (table, mask.as_slice()))
        .collect();
    let plan = {
        let guard = cache.lock().unwrap();
        plan_prefetch_union(&pairs, moe_blocks, k_used, &guard)
    };
    for fetch in plan {
        let key = fetch.key;
        let real = bundle.weights.expert_bytes(key.block, key.expert)?;
        let mut guard = cache.lock().unwrap();
        // non-blocking: prefetch misses do not stall the inference thread
        let _ = guard.ensure(key, real, false, || {
            crate::runtime::stage_expert_parts(
                &bundle.engine,
                &bundle.weights,
                key.block,
                key.expert,
            )
        })?;
    }
    Ok(())
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[2.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0); // first wins ties
    }

    #[test]
    fn default_config_sane() {
        let c = PipelineConfig::default();
        assert_eq!(c.k_used, 1);
        assert_eq!(c.policy, "fifo");
        assert!(c.prefetch);
    }
}
