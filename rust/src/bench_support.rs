//! Shared harness for the bench targets (`rust/benches/*`) and examples.
//!
//! Each bench regenerates one of the paper's tables/figures (DESIGN.md
//! §4); this module provides artifact loading with a skip-if-missing
//! escape hatch, the method-dispatch wrapper, CSV output beside the
//! printed table (`target/bench_results/*.csv`), and the
//! machine-readable perf-trajectory emitter ([`BenchJson`]):
//! `BENCH_<name>.json` files that future PRs diff to catch silent
//! performance regressions.  Set `SIDA_BENCH_JSON=<dir>` to redirect
//! where the JSON lands (default: `target/bench_results/`).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::baselines::{run_baseline, BaselineConfig, Method};
use crate::coordinator::{Pipeline, PipelineConfig, ServeOutcome};
use crate::metrics::{ServeStats, Table};
use crate::runtime::ModelBundle;
use crate::util::json::Json;
use crate::workload::{ArrivalProcess, Profile, Request, TraceGenerator};

pub const ALL_MODELS: [&str; 4] = ["switch8", "switch64", "switch128", "switch256"];
pub const ACCURACY_MODELS: [&str; 2] = ["switch8", "switch128"];
pub const ALL_DATASETS: [&str; 3] = ["sst2", "mrpc", "multirc"];

/// Artifacts root, or exit 0 with a message (benches must not fail CI
/// when artifacts are absent).
pub fn artifacts_or_exit() -> PathBuf {
    if !cfg!(feature = "pjrt") {
        println!(
            "SKIP bench: built without the `pjrt` feature — artifact-backed \
             benches need `cargo bench --features pjrt` (see DESIGN.md)"
        );
        std::process::exit(0);
    }
    let root = crate::default_artifacts_root();
    if !root.join("switch8").join("model.json").is_file() {
        println!("SKIP bench: artifacts not built — run `make artifacts` first");
        std::process::exit(0);
    }
    root
}

pub fn load(name: &str) -> Result<Arc<ModelBundle>> {
    let root = artifacts_or_exit();
    Ok(Arc::new(ModelBundle::load_named(&root, name)?))
}

/// Generate the standard closed-loop trace for one dataset.
pub fn trace_for(bundle: &ModelBundle, dataset: &str, n: usize, seed: u64) -> Vec<Request> {
    let mut gen = TraceGenerator::new(
        Profile::named(dataset).expect("profile"),
        bundle.topology.vocab,
        seed,
    );
    gen.trace(n, ArrivalProcess::ClosedLoop)
}

#[derive(Debug, Clone)]
pub struct RunSpec {
    pub dataset: String,
    pub n_requests: usize,
    pub budget_sim_bytes: usize,
    pub real_sleep: bool,
    pub k_used: usize,
    pub want_lm: bool,
    pub want_cls: bool,
    pub policy: String,
    /// modeled host-RAM tier budget (sim bytes; device evictions demote
    /// here, overflow falls to SSD)
    pub ram_budget_sim_bytes: usize,
    /// the RAM window's own eviction policy
    pub ram_policy: String,
    pub prefetch: bool,
    /// requests per forward (sida only): 1 = the paper's batch-1 mode,
    /// > 1 = cross-request batching
    pub max_batch: usize,
    /// worker-pool width for expert execution (0 = auto, 1 = the fully
    /// sequential reference path)
    pub pool_threads: usize,
    /// modeled devices for expert parallelism (sida only; 1 = the
    /// single-device path, budget is per device)
    pub devices: usize,
    /// hottest experts per MoE layer replicated across the fleet
    pub replicate_top: usize,
    /// availability floor: holders per predicted-hot expert (cluster)
    pub min_replicas: usize,
    /// deterministic fault schedule ("" = fault-free; cluster only)
    pub fault_plan: String,
    /// on-disk expert store directory ("" = store-less, modeled SSD
    /// only); reopening the same dir serves restart-warm
    pub store_dir: String,
    /// on-disk store budget in real bytes (0 = unbounded)
    pub ssd_budget_bytes: usize,
    /// MoE layers the depth-window warmer may stage ahead (1 = the
    /// one-layer-ahead baseline, 3 = the cross-layer scheduler default)
    pub prefetch_depth: usize,
    /// modeled host staging bandwidth in bytes/sec (0 = reference link)
    pub host_bw: f64,
    pub seed: u64,
}

impl RunSpec {
    pub fn new(dataset: &str, n_requests: usize) -> Self {
        RunSpec {
            dataset: dataset.to_string(),
            n_requests,
            budget_sim_bytes: 80_000_000_000, // A100-80GB-like default
            real_sleep: true,
            k_used: crate::config::ServeConfig::paper_k_for(dataset),
            want_lm: false,
            want_cls: false,
            policy: "fifo".into(),
            ram_budget_sim_bytes: crate::memory::DEFAULT_RAM_BUDGET,
            ram_policy: "fifo".into(),
            prefetch: true,
            max_batch: 1,
            pool_threads: 0,
            devices: 1,
            replicate_top: 1,
            min_replicas: 1,
            fault_plan: String::new(),
            store_dir: String::new(),
            ssd_budget_bytes: 0,
            prefetch_depth: 3,
            host_bw: 0.0,
            seed: 0,
        }
    }

    /// Cross-layer prefetch depth (1 = one-layer-ahead baseline).
    pub fn prefetch_depth(mut self, d: usize) -> Self {
        self.prefetch_depth = d.max(1);
        self
    }

    /// Modeled host staging bandwidth in bytes/sec (0 = reference).
    pub fn host_bw(mut self, bw: f64) -> Self {
        self.host_bw = bw.max(0.0);
        self
    }

    pub fn batch(mut self, b: usize) -> Self {
        self.max_batch = b.max(1);
        self
    }

    /// Worker-pool width (0 = auto, 1 = sequential reference).
    pub fn pool(mut self, threads: usize) -> Self {
        self.pool_threads = threads;
        self
    }

    /// Modeled device count (1 = single device).
    pub fn devices(mut self, n: usize) -> Self {
        self.devices = n.max(1);
        self
    }

    /// Hot-expert replication factor (cluster mode).
    pub fn replicate(mut self, r: usize) -> Self {
        self.replicate_top = r;
        self
    }

    /// Availability floor: holders per predicted-hot expert (cluster).
    pub fn min_replicas(mut self, k: usize) -> Self {
        self.min_replicas = k.max(1);
        self
    }

    /// Deterministic fault schedule (`--fault-plan` grammar).
    pub fn faults(mut self, plan: &str) -> Self {
        self.fault_plan = plan.to_string();
        self
    }

    pub fn budget(mut self, bytes: usize) -> Self {
        self.budget_sim_bytes = bytes;
        self
    }

    pub fn lm(mut self, v: bool) -> Self {
        self.want_lm = v;
        self
    }

    pub fn cls(mut self, v: bool) -> Self {
        self.want_cls = v;
        self
    }

    pub fn sleep(mut self, v: bool) -> Self {
        self.real_sleep = v;
        self
    }

    pub fn k(mut self, k: usize) -> Self {
        self.k_used = k;
        self
    }

    pub fn policy_name(mut self, p: &str) -> Self {
        self.policy = p.to_string();
        self
    }

    /// Modeled host-RAM tier budget in simulated bytes (`--ram-budget`).
    pub fn ram_budget(mut self, bytes: usize) -> Self {
        self.ram_budget_sim_bytes = bytes;
        self
    }

    /// RAM-tier eviction policy (`--ram-policy`).
    pub fn ram_policy_name(mut self, p: &str) -> Self {
        self.ram_policy = p.to_string();
        self
    }

    pub fn prefetch_on(mut self, v: bool) -> Self {
        self.prefetch = v;
        self
    }

    /// On-disk expert store directory (`--store-dir`).
    pub fn store(mut self, dir: &str) -> Self {
        self.store_dir = dir.to_string();
        self
    }

    /// On-disk store budget in real bytes (`--ssd-budget`).
    pub fn ssd_budget(mut self, bytes: usize) -> Self {
        self.ssd_budget_bytes = bytes;
        self
    }
}

/// Run one (method, model, dataset) cell and return the outcome.
///
/// A short unmeasured warmup trace runs first, mirroring the paper's
/// steady-state measurement over full test sets: the baselines start
/// with all weights staged (their load time is never counted), so SiDA
/// gets its caches warm and its executables dispatched once before the
/// clock starts.  Cache statistics are reset after warmup.
pub fn run_method(
    bundle: Arc<ModelBundle>,
    method: Method,
    spec: &RunSpec,
) -> Result<ServeOutcome> {
    let warmup = trace_for(&bundle, &spec.dataset, 4, spec.seed ^ 0xA5A5);
    let requests = trace_for(&bundle, &spec.dataset, spec.n_requests, spec.seed);
    match method {
        Method::Sida => {
            let cfg = PipelineConfig {
                k_used: spec.k_used,
                budget_sim_bytes: spec.budget_sim_bytes,
                policy: spec.policy.clone(),
                ram_budget_bytes: spec.ram_budget_sim_bytes,
                ram_policy: spec.ram_policy.clone(),
                store_dir: spec.store_dir.clone(),
                ssd_budget_bytes: spec.ssd_budget_bytes,
                real_sleep: spec.real_sleep,
                prefetch: spec.prefetch,
                prefetch_depth: spec.prefetch_depth,
                host_bw: spec.host_bw,
                queue_depth: 8,
                max_batch: spec.max_batch,
                pool_threads: spec.pool_threads,
                devices: spec.devices,
                replicate_top: spec.replicate_top,
                min_replicas: spec.min_replicas,
                fault_plan: spec.fault_plan.clone(),
                want_lm: spec.want_lm,
                want_cls: spec.want_cls,
            };
            let pipeline = Pipeline::new(bundle, &spec.dataset, cfg)?;
            let _ = pipeline.serve(&warmup)?;
            pipeline.reset_serving_stats();
            pipeline.serve(&requests)
        }
        m => {
            let cfg = BaselineConfig {
                budget_sim_bytes: spec.budget_sim_bytes,
                ram_budget_sim_bytes: spec.ram_budget_sim_bytes,
                ram_policy: spec.ram_policy.clone(),
                real_sleep: spec.real_sleep,
                want_lm: spec.want_lm,
                want_cls: spec.want_cls,
            };
            let _ = run_baseline(bundle.clone(), &spec.dataset, m, &warmup, &cfg)?;
            run_baseline(bundle, &spec.dataset, m, &requests, &cfg)
        }
    }
}

/// Paper-scale simulated bytes of one expert — for sizing device
/// budgets in expert units (e.g. the tight-budget batching comparison).
pub fn sim_expert_bytes(bundle: &ModelBundle) -> Result<usize> {
    let real = bundle.weights.expert_bytes(bundle.topology.moe_blocks[0], 0)?;
    Ok(crate::memory::CostModel::paper_scale(real).sim_expert_bytes)
}

/// Quick-mode request count from BENCH_QUICK env (CI) vs default.
pub fn n_requests(default: usize) -> usize {
    match std::env::var("BENCH_QUICK").as_deref() {
        Ok("1") | Ok("true") => (default / 4).max(2),
        _ => default,
    }
}

/// Where bench CSVs land.
pub fn csv_path(name: &str) -> String {
    format!("target/bench_results/{name}.csv")
}

/// Directory the perf-trajectory JSON lands in: `SIDA_BENCH_JSON` when
/// set, else beside the CSV tables.
pub fn bench_json_dir() -> PathBuf {
    match std::env::var("SIDA_BENCH_JSON") {
        Ok(p) if !p.is_empty() => PathBuf::from(p),
        _ => PathBuf::from("target/bench_results"),
    }
}

/// Machine-readable bench output: collects rows (arbitrary JSON
/// objects) and writes `BENCH_<name>.json` — one self-describing file
/// per bench, diffable across PRs as a performance trajectory.
///
/// ```
/// use sida_moe::bench_support::BenchJson;
/// use sida_moe::util::json::{num, obj, s};
///
/// let mut j = BenchJson::new("demo");
/// j.push(obj(vec![("mode", s("pooled")), ("modeled_ms", num(1.25))]));
/// assert!(j.render().contains("\"bench\":\"demo\""));
/// ```
pub struct BenchJson {
    name: String,
    rows: Vec<Json>,
}

impl BenchJson {
    pub fn new(name: &str) -> Self {
        BenchJson { name: name.to_string(), rows: Vec::new() }
    }

    /// Append one row (any JSON value; conventionally an object).
    pub fn push(&mut self, row: Json) {
        self.rows.push(row);
    }

    /// Append a printed [`Table`] as header-keyed string rows, so every
    /// figure's table is also machine-readable without re-deriving it.
    pub fn push_table(&mut self, table: &Table) {
        for row in &table.rows {
            let cells = table
                .headers
                .iter()
                .zip(row.iter())
                .map(|(h, c)| (h.clone(), Json::Str(c.clone())))
                .collect();
            self.rows.push(Json::Obj(cells));
        }
    }

    /// The document this emitter writes.
    pub fn render(&self) -> String {
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        Json::Obj(
            [
                ("bench".to_string(), Json::Str(self.name.clone())),
                ("generated_unix".to_string(), Json::Num(unix as f64)),
                ("rows".to_string(), Json::Arr(self.rows.clone())),
            ]
            .into_iter()
            .collect(),
        )
        .to_string()
    }

    /// Write `BENCH_<name>.json` into [`bench_json_dir`]; returns the
    /// path written.
    pub fn save(&self) -> std::io::Result<PathBuf> {
        let dir = bench_json_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

/// Modeled per-request latency in milliseconds (exposed transfer +
/// critical-path compute) — the perf-trajectory headline number.
pub fn modeled_request_ms(stats: &ServeStats) -> f64 {
    stats.modeled_request_secs().unwrap_or(0.0) * 1e3
}

/// Paper-reference banner printed by each bench.
pub fn banner(id: &str, paper_claim: &str) {
    println!("\n################################################################");
    println!("# {id}");
    println!("# paper: {paper_claim}");
    println!("# testbed: CPU PJRT + simulated device tier (DESIGN.md §2) —");
    println!("#          compare SHAPES/ratios, not absolute numbers");
    println!("################################################################");
}
