//! On-disk expert blob store: the real device behind the §6 SSD tier.
//!
//! The [`crate::memory::ResidencyLedger`] models *where* experts sit;
//! until this module the SSD tier was bookkeeping only — demotions moved
//! bytes between hash maps and promotions charged modeled NVMe seconds
//! that had never met an actual file.  `ExpertStore` is that file layer:
//! a content-addressed, integrity-hashed blob store the expert cache
//! writes on demotion and reads (with verification) on promotion, so
//! SSD promotions carry a **measured** wall-clock timeline alongside the
//! modeled one, and a restarted process reopens the store warm instead
//! of re-fabricating every expert.
//!
//! Layout under the store directory:
//!
//! ```text
//! <dir>/MANIFEST.json          key -> {hash, bytes, seq} (atomic rewrite)
//! <dir>/blobs/<hash:016x>.blob one file per distinct payload
//! ```
//!
//! * **Content addressing.**  A blob is named by the FNV-1a 64-bit hash
//!   of its payload (vendored below — the crate set has no hashing
//!   dependency).  Two experts with identical bytes share one file; a
//!   refcount per hash delays deletion until the last key departs.
//! * **Exactly-once writes.**  All mutation runs under one mutex, and a
//!   blob lands via write-to-temp + atomic rename — concurrent writers
//!   of the same content produce exactly one file, and a reader can
//!   never observe a torn blob (rename is atomic on POSIX).
//! * **Integrity.**  [`ExpertStore::get`] re-hashes what it read and
//!   compares length + hash against the manifest.  A mismatch removes
//!   the entry, counts an `integrity_failure`, and reports
//!   [`ReadOutcome::Corrupt`]; the cache then falls back to
//!   re-fabrication from the bundle (the host `WeightStore` remains
//!   authoritative), so corruption degrades to a cold miss — never a
//!   wrong answer and never a panic.
//! * **Budget.**  `--ssd-budget` bounds bytes on disk (0 = unbounded);
//!   overflow reclaims the oldest-written entries first (`seq` order),
//!   never the entry just written.
//!
//! The blob payload is the four parts of one expert (w1, b1, w2, b2)
//! behind a fixed header ([`encode_expert_payload`]); staging from a
//! verified payload produces bit-identical device buffers to staging
//! from the bundle, which is what makes restart-warm serving exact.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::experts::ExpertKey;
use crate::obs::trace::{self, ArgValue};
use crate::util::json::{num, obj, s, Json};

/// FNV-1a 64-bit: the vendored content hash (no crates.io deps).  Not
/// cryptographic — the threat model is bit rot and torn writes, not an
/// adversary choosing payloads.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Magic prefix of every expert blob payload.
pub const BLOB_MAGIC: [u8; 4] = *b"SIDX";
/// Payload format version.
pub const BLOB_VERSION: u32 = 1;
/// Header bytes ahead of the part data: magic + version + 4 part lengths.
pub const PAYLOAD_HEADER_BYTES: usize = 4 + 4 + 4 * 4;

/// Serialize the four parts of one expert (w1, b1, w2, b2 — artifact
/// argument order) into the on-disk blob payload.
pub fn encode_expert_payload(parts: &[&[u8]; 4]) -> Vec<u8> {
    let total: usize = PAYLOAD_HEADER_BYTES + parts.iter().map(|p| p.len()).sum::<usize>();
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(&BLOB_MAGIC);
    out.extend_from_slice(&BLOB_VERSION.to_le_bytes());
    for p in parts {
        out.extend_from_slice(&(p.len() as u32).to_le_bytes());
    }
    for p in parts {
        out.extend_from_slice(p);
    }
    out
}

/// Split a blob payload back into its four part byte slices, validating
/// the header and every length (a verified hash already implies these
/// hold; the checks make hand-built payloads fail loudly too).
pub fn decode_expert_payload(payload: &[u8]) -> Result<[&[u8]; 4]> {
    if payload.len() < PAYLOAD_HEADER_BYTES {
        bail!("blob payload truncated: {} bytes", payload.len());
    }
    if payload[..4] != BLOB_MAGIC {
        bail!("blob payload has bad magic");
    }
    let version = u32::from_le_bytes(payload[4..8].try_into().unwrap());
    if version != BLOB_VERSION {
        bail!("blob payload version {version} != {BLOB_VERSION}");
    }
    let mut lens = [0usize; 4];
    for (i, len) in lens.iter_mut().enumerate() {
        let off = 8 + 4 * i;
        *len = u32::from_le_bytes(payload[off..off + 4].try_into().unwrap()) as usize;
    }
    let want = PAYLOAD_HEADER_BYTES + lens.iter().sum::<usize>();
    if payload.len() != want {
        bail!("blob payload {} bytes, header implies {want}", payload.len());
    }
    let mut off = PAYLOAD_HEADER_BYTES;
    let mut parts = [&payload[0..0]; 4];
    for (i, len) in lens.iter().enumerate() {
        parts[i] = &payload[off..off + len];
        off += len;
    }
    Ok(parts)
}

/// Outcome of one [`ExpertStore::get`].
pub enum ReadOutcome {
    /// Verified payload (length and content hash match the manifest).
    Hit(Vec<u8>),
    /// The blob existed but failed verification; the entry has been
    /// dropped and an `integrity_failure` counted.  Re-fabricate.
    Corrupt,
    /// No (readable) blob for this key — a clean miss.  Re-fabricate.
    Miss,
}

/// Counters + occupancy snapshot of one store.  Seconds are **measured**
/// wall clock around the real file I/O — the honest companion to the
/// ledger's modeled NVMe seconds, never a replacement for them.
#[derive(Debug, Default, Clone)]
pub struct StoreStats {
    /// blobs written (deduplicated re-puts of identical content not
    /// included)
    pub writes: u64,
    /// verified reads (promotions served from disk)
    pub reads: u64,
    /// `get` calls with no readable blob (never stored, reclaimed, or
    /// the file vanished underneath the manifest)
    pub misses: u64,
    /// verification failures (bad length or hash) and payloads the
    /// cache rejected at staging time
    pub integrity_failures: u64,
    /// SSD-tier promotions that fell back to bundle re-fabrication
    pub refabrications: u64,
    /// entries reclaimed by the `--ssd-budget` bound
    pub reclaimed: u64,
    /// measured wall seconds spent in blob writes
    pub write_secs: f64,
    /// measured wall seconds spent in (verified) blob reads
    pub read_secs: f64,
    /// bytes currently on disk across distinct blobs (du-style)
    pub bytes_on_disk: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    hash: u64,
    bytes: u64,
    /// write order, for oldest-first reclamation
    seq: u64,
}

struct Inner {
    entries: BTreeMap<ExpertKey, Entry>,
    /// keys per distinct blob; the file is deleted when this hits zero
    hash_refs: HashMap<u64, usize>,
    next_seq: u64,
    stats: StoreStats,
}

/// The content-addressed on-disk expert store.  One instance per store
/// directory; share it via `Arc` (all mutation is internally locked).
pub struct ExpertStore {
    dir: PathBuf,
    /// bytes-on-disk bound, 0 = unbounded (`--ssd-budget`)
    budget: u64,
    inner: Mutex<Inner>,
}

impl ExpertStore {
    /// Open (or create) the store at `dir`.  An existing `MANIFEST.json`
    /// is reloaded — that is what makes a restarted server warm — and
    /// orphan blob files (a crash between blob rename and manifest
    /// rewrite) are swept so disk accounting matches enumeration.
    pub fn open(dir: &Path, budget_bytes: u64) -> Result<Arc<ExpertStore>> {
        std::fs::create_dir_all(dir.join("blobs"))
            .with_context(|| format!("creating expert store at {}", dir.display()))?;
        let mut inner = Inner {
            entries: BTreeMap::new(),
            hash_refs: HashMap::new(),
            next_seq: 0,
            stats: StoreStats::default(),
        };
        let manifest = dir.join("MANIFEST.json");
        if manifest.exists() {
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {}", manifest.display()))?;
            let j = Json::parse(&text).context("parsing store MANIFEST.json")?;
            for e in j.get("entries")?.as_arr()? {
                let key = ExpertKey::new(e.get_usize("block")?, e.get_usize("expert")?);
                let hash = u64::from_str_radix(e.get_str("hash")?, 16)
                    .context("bad hash in store manifest")?;
                let bytes = e.get_usize("bytes")? as u64;
                let seq = e.get_usize("seq")? as u64;
                inner.next_seq = inner.next_seq.max(seq + 1);
                if inner.entries.insert(key, Entry { hash, bytes, seq }).is_none() {
                    let refs = inner.hash_refs.entry(hash).or_insert(0);
                    if *refs == 0 {
                        inner.stats.bytes_on_disk += bytes;
                    }
                    *refs += 1;
                }
            }
        }
        let store = ExpertStore { dir: dir.to_path_buf(), budget: budget_bytes, inner: Mutex::new(inner) };
        store.sweep_orphans()?;
        Ok(Arc::new(store))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Keys on disk with their payload bytes — the ledger pre-seeds its
    /// SSD tier from this at attach time.
    pub fn keys_with_bytes(&self) -> Vec<(ExpertKey, u64)> {
        let inner = self.inner.lock().unwrap();
        inner.entries.iter().map(|(k, e)| (*k, e.bytes)).collect()
    }

    pub fn stats(&self) -> StoreStats {
        self.inner.lock().unwrap().stats.clone()
    }

    /// Zero the traffic counters (a new measurement epoch); occupancy —
    /// what is on disk — is state, not statistics, and carries over.
    pub fn reset_stats(&self) {
        let mut inner = self.inner.lock().unwrap();
        let bytes = inner.stats.bytes_on_disk;
        inner.stats = StoreStats { bytes_on_disk: bytes, ..StoreStats::default() };
    }

    fn blob_path(&self, hash: u64) -> PathBuf {
        self.dir.join("blobs").join(format!("{hash:016x}.blob"))
    }

    /// Write `payload` for `key`.  Content-addressed: identical payloads
    /// (same or different key) share one blob file; a re-put of what a
    /// key already holds is a no-op.  Exactly-once under concurrency:
    /// registration runs under the store mutex and the file lands via
    /// temp + atomic rename.
    pub fn put(&self, key: ExpertKey, payload: &[u8]) -> Result<()> {
        let hash = fnv1a64(payload);
        let mut inner = self.inner.lock().unwrap();
        if let Some(existing) = inner.entries.get(&key) {
            if existing.hash == hash {
                return Ok(()); // already stored, content unchanged
            }
            // expert content changed (never happens for immutable
            // checkpoints, but stay correct): drop the stale mapping
            let stale = existing.clone();
            inner.entries.remove(&key);
            Self::release_hash(&self.dir, &mut inner, stale.hash, stale.bytes);
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        let refs = *inner.hash_refs.get(&hash).unwrap_or(&0);
        if refs == 0 {
            // first key with this content: the blob must hit the disk
            let t_span = trace::begin();
            let t0 = Instant::now();
            let tmp = self
                .dir
                .join("blobs")
                .join(format!(".tmp-{hash:016x}-{}", std::process::id()));
            std::fs::write(&tmp, payload)
                .with_context(|| format!("writing {}", tmp.display()))?;
            std::fs::rename(&tmp, self.blob_path(hash))
                .with_context(|| format!("publishing blob {hash:016x}"))?;
            inner.stats.write_secs += t0.elapsed().as_secs_f64();
            inner.stats.writes += 1;
            inner.stats.bytes_on_disk += payload.len() as u64;
            if trace::enabled() {
                trace::complete(
                    "store_write",
                    "store",
                    trace::host_pid(),
                    t_span,
                    vec![
                        ("block", ArgValue::U(key.block as u64)),
                        ("expert", ArgValue::U(key.expert as u64)),
                        ("bytes", ArgValue::U(payload.len() as u64)),
                    ],
                );
            }
        }
        *inner.hash_refs.entry(hash).or_insert(0) += 1;
        inner.entries.insert(key, Entry { hash, bytes: payload.len() as u64, seq });
        if self.budget > 0 {
            self.reclaim_over_budget(&mut inner, key);
        }
        self.persist_manifest(&inner)?;
        Ok(())
    }

    /// Read and verify the blob for `key`.  Holds the store mutex across
    /// the file read so no reclaim or rewrite can race it — with rename-
    /// atomic publication this is what "no torn reads" means here.
    pub fn get(&self, key: &ExpertKey) -> ReadOutcome {
        let mut inner = self.inner.lock().unwrap();
        let Some(entry) = inner.entries.get(key).cloned() else {
            inner.stats.misses += 1;
            return ReadOutcome::Miss;
        };
        let t_span = trace::begin();
        let t0 = Instant::now();
        let data = match std::fs::read(self.blob_path(entry.hash)) {
            Ok(d) => d,
            Err(_) => {
                // manifest-listed but unreadable (deleted underneath
                // us): clean miss, and drop the dangling entry
                inner.entries.remove(key);
                Self::release_hash(&self.dir, &mut inner, entry.hash, entry.bytes);
                inner.stats.misses += 1;
                let _ = self.persist_manifest(&inner);
                return ReadOutcome::Miss;
            }
        };
        if data.len() as u64 == entry.bytes && fnv1a64(&data) == entry.hash {
            inner.stats.read_secs += t0.elapsed().as_secs_f64();
            inner.stats.reads += 1;
            if trace::enabled() {
                trace::complete(
                    "store_read",
                    "store",
                    trace::host_pid(),
                    t_span,
                    vec![
                        ("block", ArgValue::U(key.block as u64)),
                        ("expert", ArgValue::U(key.expert as u64)),
                        ("bytes", ArgValue::U(data.len() as u64)),
                    ],
                );
            }
            ReadOutcome::Hit(data)
        } else {
            log::warn!(
                "expert store: blob {:016x} for {key:?} failed verification \
                 ({} bytes on disk, {} expected) — falling back to re-fabrication",
                entry.hash,
                data.len(),
                entry.bytes
            );
            inner.entries.remove(key);
            Self::release_hash(&self.dir, &mut inner, entry.hash, entry.bytes);
            inner.stats.integrity_failures += 1;
            let _ = self.persist_manifest(&inner);
            ReadOutcome::Corrupt
        }
    }

    /// The cache verified the hash but could not stage the payload
    /// (header/shape mismatch): treat as corruption — drop the entry and
    /// count an integrity failure.
    pub fn reject(&self, key: &ExpertKey) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(entry) = inner.entries.remove(key) {
            Self::release_hash(&self.dir, &mut inner, entry.hash, entry.bytes);
            let _ = self.persist_manifest(&inner);
        }
        inner.stats.integrity_failures += 1;
    }

    /// Count one SSD-tier promotion that fell back to bundle
    /// re-fabrication (the cache calls this after `Miss`/`Corrupt`).
    pub fn note_refabrication(&self) {
        self.inner.lock().unwrap().stats.refabrications += 1;
    }

    /// Drop one key's refcount on `hash`; delete the blob (and its disk
    /// bytes) when the last reference departs.
    fn release_hash(dir: &Path, inner: &mut Inner, hash: u64, bytes: u64) {
        let gone = match inner.hash_refs.get_mut(&hash) {
            Some(r) => {
                *r = r.saturating_sub(1);
                *r == 0
            }
            None => false,
        };
        if gone {
            inner.hash_refs.remove(&hash);
            let _ = std::fs::remove_file(dir.join("blobs").join(format!("{hash:016x}.blob")));
            inner.stats.bytes_on_disk = inner.stats.bytes_on_disk.saturating_sub(bytes);
        }
    }

    /// Oldest-first reclamation down to the byte budget, never evicting
    /// the entry just written (`keep`).
    fn reclaim_over_budget(&self, inner: &mut Inner, keep: ExpertKey) {
        while inner.stats.bytes_on_disk > self.budget {
            let victim = inner
                .entries
                .iter()
                .filter(|(k, _)| **k != keep)
                .min_by_key(|(_, e)| e.seq)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { break };
            let entry = inner.entries.remove(&victim).expect("victim chosen from entries");
            Self::release_hash(&self.dir, inner, entry.hash, entry.bytes);
            inner.stats.reclaimed += 1;
        }
    }

    /// Rewrite MANIFEST.json atomically (temp + rename) to reflect the
    /// in-memory entry table.
    fn persist_manifest(&self, inner: &Inner) -> Result<()> {
        let entries: Vec<Json> = inner
            .entries
            .iter()
            .map(|(k, e)| {
                obj(vec![
                    ("block", num(k.block as f64)),
                    ("expert", num(k.expert as f64)),
                    ("hash", s(&format!("{:016x}", e.hash))),
                    ("bytes", num(e.bytes as f64)),
                    ("seq", num(e.seq as f64)),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("version", num(1.0)),
            ("entries", Json::Arr(entries)),
        ]);
        let tmp = self.dir.join(format!(".MANIFEST.tmp-{}", std::process::id()));
        std::fs::write(&tmp, doc.to_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, self.dir.join("MANIFEST.json"))
            .context("publishing store manifest")?;
        Ok(())
    }

    /// Delete blob files no manifest entry references (left by a crash
    /// between blob rename and manifest rewrite), stale blob temp
    /// files, and torn `.MANIFEST.tmp-*` leftovers at the store root (a
    /// crash inside `persist_manifest` before the rename publishes —
    /// `MANIFEST.json` itself is never touched until the rename, so the
    /// leftover is pure garbage).
    fn sweep_orphans(&self) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        for dirent in std::fs::read_dir(self.dir.join("blobs"))? {
            let path = dirent?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            let live = name
                .strip_suffix(".blob")
                .and_then(|h| u64::from_str_radix(h, 16).ok())
                .is_some_and(|h| inner.hash_refs.contains_key(&h));
            if !live {
                let _ = std::fs::remove_file(&path);
            }
        }
        for dirent in std::fs::read_dir(&self.dir)? {
            let path = dirent?.path();
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name.starts_with(".MANIFEST.tmp-") {
                let _ = std::fs::remove_file(&path);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("sida_store_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn k(e: usize) -> ExpertKey {
        ExpertKey::new(0, e)
    }

    fn du(dir: &Path) -> u64 {
        std::fs::read_dir(dir.join("blobs"))
            .unwrap()
            .map(|e| e.unwrap().metadata().unwrap().len())
            .sum()
    }

    #[test]
    fn fnv1a64_known_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
    }

    #[test]
    fn payload_roundtrip_and_rejects() {
        let parts: [&[u8]; 4] = [b"wwww", b"b", b"WWWWWW", b"B"];
        let payload = encode_expert_payload(&parts);
        assert_eq!(payload.len(), PAYLOAD_HEADER_BYTES + 12);
        let back = decode_expert_payload(&payload).unwrap();
        assert_eq!(back, parts);
        assert!(decode_expert_payload(&payload[..10]).is_err());
        let mut bad_magic = payload.clone();
        bad_magic[0] ^= 0xff;
        assert!(decode_expert_payload(&bad_magic).is_err());
        let mut truncated = payload.clone();
        truncated.pop();
        assert!(decode_expert_payload(&truncated).is_err());
    }

    #[test]
    fn put_get_roundtrip_with_stats() {
        let dir = tmp("roundtrip");
        let store = ExpertStore::open(&dir, 0).unwrap();
        store.put(k(0), b"hello expert").unwrap();
        match store.get(&k(0)) {
            ReadOutcome::Hit(d) => assert_eq!(d, b"hello expert"),
            _ => panic!("expected hit"),
        }
        let st = store.stats();
        assert_eq!(st.writes, 1);
        assert_eq!(st.reads, 1);
        assert_eq!(st.bytes_on_disk, 12);
        assert_eq!(st.bytes_on_disk, du(&dir));
        assert!(st.write_secs > 0.0 && st.read_secs > 0.0);
        // re-put of identical content is a no-op
        store.put(k(0), b"hello expert").unwrap();
        assert_eq!(store.stats().writes, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn identical_payloads_share_one_blob() {
        let dir = tmp("dedup");
        let store = ExpertStore::open(&dir, 0).unwrap();
        store.put(k(0), b"same bytes").unwrap();
        store.put(k(1), b"same bytes").unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.stats().writes, 1, "second put must dedup");
        let files = std::fs::read_dir(dir.join("blobs")).unwrap().count();
        assert_eq!(files, 1);
        assert_eq!(store.stats().bytes_on_disk, 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reopen_restores_entries() {
        let dir = tmp("reopen");
        {
            let store = ExpertStore::open(&dir, 0).unwrap();
            store.put(k(3), b"persistent").unwrap();
        }
        let store = ExpertStore::open(&dir, 0).unwrap();
        assert_eq!(store.keys_with_bytes(), vec![(k(3), 10)]);
        match store.get(&k(3)) {
            ReadOutcome::Hit(d) => assert_eq!(d, b"persistent"),
            _ => panic!("reopened store must hit"),
        }
        assert_eq!(store.stats().bytes_on_disk, du(&dir));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_is_detected_and_entry_dropped() {
        let dir = tmp("corrupt");
        let store = ExpertStore::open(&dir, 0).unwrap();
        store.put(k(0), b"pristine content").unwrap();
        let blob = std::fs::read_dir(dir.join("blobs")).unwrap().next().unwrap().unwrap().path();
        let mut bytes = std::fs::read(&blob).unwrap();
        bytes[4] ^= 0x01;
        std::fs::write(&blob, &bytes).unwrap();
        assert!(matches!(store.get(&k(0)), ReadOutcome::Corrupt));
        assert_eq!(store.stats().integrity_failures, 1);
        // the entry is gone: the next lookup is a clean miss
        assert!(matches!(store.get(&k(0)), ReadOutcome::Miss));
        assert_eq!(store.stats().bytes_on_disk, du(&dir));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_blob_is_a_clean_miss() {
        let dir = tmp("missing");
        let store = ExpertStore::open(&dir, 0).unwrap();
        store.put(k(0), b"soon gone").unwrap();
        let blob = std::fs::read_dir(dir.join("blobs")).unwrap().next().unwrap().unwrap().path();
        std::fs::remove_file(&blob).unwrap();
        assert!(matches!(store.get(&k(0)), ReadOutcome::Miss));
        assert_eq!(store.stats().misses, 1);
        assert_eq!(store.stats().integrity_failures, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ssd_budget_reclaims_oldest_first() {
        let dir = tmp("budget");
        // room for two 8-byte payloads
        let store = ExpertStore::open(&dir, 16).unwrap();
        store.put(k(0), b"payload0").unwrap();
        store.put(k(1), b"payload1").unwrap();
        store.put(k(2), b"payload2").unwrap(); // over budget: k0 (oldest) goes
        let st = store.stats();
        assert_eq!(st.reclaimed, 1);
        assert!(st.bytes_on_disk <= 16);
        assert_eq!(st.bytes_on_disk, du(&dir));
        assert!(matches!(store.get(&k(0)), ReadOutcome::Miss));
        assert!(matches!(store.get(&k(1)), ReadOutcome::Hit(_)));
        assert!(matches!(store.get(&k(2)), ReadOutcome::Hit(_)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn orphan_blobs_are_swept_on_open() {
        let dir = tmp("orphan");
        {
            let store = ExpertStore::open(&dir, 0).unwrap();
            store.put(k(0), b"kept").unwrap();
        }
        std::fs::write(dir.join("blobs").join("deadbeefdeadbeef.blob"), b"orphan").unwrap();
        std::fs::write(dir.join("blobs").join(".tmp-stale-123"), b"tmp").unwrap();
        let store = ExpertStore::open(&dir, 0).unwrap();
        assert_eq!(std::fs::read_dir(dir.join("blobs")).unwrap().count(), 1);
        assert_eq!(store.stats().bytes_on_disk, du(&dir));
        std::fs::remove_dir_all(&dir).ok();
    }
}
