//! Transfer cost model for the simulated GPU tier.
//!
//! The testbed has no GPU, so "GPU memory" is a byte-budgeted tier and
//! H2D/D2H transfers carry a modeled cost (DESIGN.md §2).  The paper's
//! headline numbers are ratios driven by (a) resident bytes and (b) how
//! many transfers/invocations sit on the critical path, so a
//! bandwidth+latency model at *paper scale* preserves every shape.
//!
//! Paper scale: a Switch-base expert is two 768x3072 fp32 matrices ≈
//! 18.9 MB; over PCIe 4.0 x16 at ~16 GB/s effective + ~30 us launch
//! latency, one expert transfer ≈ 1.2 ms.  The repro's physical experts
//! are only ~66 KB (tiny dims), so the cost model scales accounting by
//! `sim_expert_bytes / real_expert_bytes`; pools and Fig 8/11 sweeps
//! report simulated GB, matching the paper's axes.

#[derive(Debug, Clone)]
pub struct CostModel {
    /// effective host->device bandwidth, bytes/sec
    pub h2d_bandwidth: f64,
    /// fixed per-transfer latency, seconds
    pub h2d_latency: f64,
    /// simulated (paper-scale) bytes of one expert's weights
    pub sim_expert_bytes: usize,
    /// physical bytes of one expert in this repro (from the manifest)
    pub real_expert_bytes: usize,
    /// if true the fetching thread actually sleeps the modeled cost on
    /// its own timeline (honest end-to-end wall clock — blocking
    /// fetches stall inference, prefetch fetches occupy the warmer); if
    /// false the cost is tracked virtually only (fast sweeps)
    pub real_sleep: bool,
}

impl CostModel {
    /// Paper-scale defaults (Switch-base expert over PCIe 4.0 x16).
    pub fn paper_scale(real_expert_bytes: usize) -> Self {
        CostModel {
            h2d_bandwidth: 16.0e9,
            h2d_latency: 30.0e-6,
            sim_expert_bytes: 2 * 768 * 3072 * 4 + (3072 + 768) * 4,
            real_expert_bytes: real_expert_bytes.max(1),
            real_sleep: false,
        }
    }

    /// Accounting at physical scale (no inflation) — unit tests.
    pub fn physical(real_expert_bytes: usize) -> Self {
        CostModel {
            h2d_bandwidth: 16.0e9,
            h2d_latency: 30.0e-6,
            sim_expert_bytes: real_expert_bytes.max(1),
            real_expert_bytes: real_expert_bytes.max(1),
            real_sleep: false,
        }
    }

    pub fn with_real_sleep(mut self, v: bool) -> Self {
        self.real_sleep = v;
        self
    }

    /// The §6 tier-ladder cost table this model's PCIe parameters imply:
    /// the RAM -> device hop is exactly this cost model's H2D link (so a
    /// RAM-resident miss costs what misses historically cost), plus the
    /// default NVMe numbers for the SSD -> RAM hop.  This is what makes
    /// the ladder and the cache share ONE modeled-transfer vocabulary.
    pub fn tier_costs(&self) -> crate::memory::TierCosts {
        crate::memory::TierCosts {
            pcie_bw: self.h2d_bandwidth,
            pcie_latency: self.h2d_latency,
            ..crate::memory::TierCosts::default()
        }
    }

    /// Simulated bytes corresponding to `real_bytes` of weights.
    pub fn sim_bytes(&self, real_bytes: usize) -> usize {
        ((real_bytes as u128 * self.sim_expert_bytes as u128)
            / self.real_expert_bytes as u128) as usize
    }

    /// Modeled seconds to move `sim_bytes` over the PCIe host->device
    /// link — the RAM->device hop of the §6 ladder
    /// ([`CostModel::tier_costs`] mirrors these parameters, so
    /// `transfer_secs(b) == promote_secs(Tier::Ram, b)` by
    /// construction).  The serving path charges misses through the
    /// ladder ([`crate::memory::ResidencyLedger::promote`]): an expert
    /// one hop away pays exactly this; an SSD-deep one pays NVMe +
    /// PCIe.
    ///
    /// Transfers are accounted on one of **two timelines**: fetches
    /// that stall the inference thread (`blocking` in the cache API)
    /// land on the critical path, while prefetch-stage / layer-ahead
    /// warmer fetches run on the prefetch timeline concurrently with
    /// compute.  Both cost the same modeled seconds (the PCIe link is
    /// busy either way); the split is recorded by the cache
    /// (`CacheStats::overlapped_transfer_secs`) and only the exposed
    /// difference is billed to modeled per-request latency.  In
    /// `real_sleep` mode the *fetching caller* sleeps these seconds on
    /// its own thread, outside any cache lock (`ExpertCache::ensure`,
    /// `SharedExpertCache::ensure_impl`) — which is exactly what makes
    /// the overlap real without serializing concurrent cache hits.
    pub fn transfer_secs(&self, sim_bytes: usize) -> f64 {
        self.h2d_latency + sim_bytes as f64 / self.h2d_bandwidth
    }
}

/// Critical-path ("exposed") share of a modeled transfer total after
/// `overlapped` seconds were hidden behind compute on the prefetch
/// timeline.  Never negative: a fully overlapped run exposes zero.
pub fn exposed_transfer_secs(modeled: f64, overlapped: f64) -> f64 {
    (modeled - overlapped).max(0.0)
}

/// Modeled staging window of ONE MoE layer: the time the prefetch link
/// has per layer of compute, estimated as the layer's predicted expert
/// set moved over the RAM -> device hop (`experts_in_layer` distinct
/// predicted experts of `sim_expert_bytes` each).  The deadline and
/// lead arithmetic of the cross-layer prefetch scheduler
/// ([`crate::experts::BandwidthWindow`]) is denominated in these
/// windows, so it is cost-model-derived and deterministic — no wall
/// clock in the schedule.
pub fn layer_window_secs(
    costs: &crate::memory::TierCosts,
    sim_expert_bytes: usize,
    experts_in_layer: usize,
) -> f64 {
    experts_in_layer.max(1) as f64
        * costs.promote_secs(crate::memory::Tier::Ram, sim_expert_bytes)
}

/// Tier-derived staging lead: how many layers ahead of compute a fetch
/// from `tier` must start for its ladder seconds to fit inside the
/// layer windows before its deadline —
/// `ceil(promote_secs(tier) / layer_window)`, clamped to
/// `[1, max_lead]`.  Device-resident experts need no staging (lead 0).
/// With default [`crate::memory::TierCosts`] an SSD-deep expert lands
/// at 2–3 layers of lead for typical per-layer expert counts, a
/// RAM-resident hop at 1 — exactly the ladder ratio (~9x) folded into
/// layer units.
pub fn lead_layers(
    costs: &crate::memory::TierCosts,
    tier: crate::memory::Tier,
    sim_expert_bytes: usize,
    experts_in_layer: usize,
    max_lead: usize,
) -> usize {
    if tier == crate::memory::Tier::Device {
        return 0;
    }
    let window = layer_window_secs(costs, sim_expert_bytes, experts_in_layer);
    let need = costs.promote_secs(tier, sim_expert_bytes);
    let lead = if window > 0.0 { (need / window).ceil() as usize } else { 1 };
    lead.clamp(1, max_lead.max(1))
}

/// Deadline of a fetch issued `layers_ahead` layers before its layer's
/// compute begins: that many layer windows from now, on the modeled
/// timeline the bandwidth window charges against.
pub fn fetch_deadline_secs(
    costs: &crate::memory::TierCosts,
    sim_expert_bytes: usize,
    experts_in_layer: usize,
    layers_ahead: usize,
) -> f64 {
    layers_ahead as f64 * layer_window_secs(costs, sim_expert_bytes, experts_in_layer)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_expert_is_millisecond_class() {
        let cm = CostModel::paper_scale(66_048);
        let secs = cm.transfer_secs(cm.sim_expert_bytes);
        assert!(secs > 0.8e-3 && secs < 3.0e-3, "got {secs}");
    }

    #[test]
    fn sim_bytes_scales_linearly() {
        let cm = CostModel::paper_scale(66_048);
        let one = cm.sim_bytes(66_048);
        assert_eq!(one, cm.sim_expert_bytes);
        let half = cm.sim_bytes(33_024);
        assert!((half as i64 - (one / 2) as i64).abs() <= 1);
    }

    #[test]
    fn physical_model_is_identity() {
        let cm = CostModel::physical(1000);
        assert_eq!(cm.sim_bytes(1000), 1000);
        assert_eq!(cm.sim_bytes(500), 500);
    }

    #[test]
    fn latency_floor() {
        let cm = CostModel::paper_scale(66_048);
        assert!(cm.transfer_secs(0) >= 30.0e-6);
    }

    #[test]
    fn tier_costs_mirror_the_h2d_link() {
        // the ladder's RAM->device hop IS the cost model's PCIe link:
        // a RAM-resident miss costs exactly what misses always cost
        let cm = CostModel::paper_scale(66_048);
        let tc = cm.tier_costs();
        let b = 1 << 20;
        assert_eq!(cm.transfer_secs(b), tc.promote_secs(crate::memory::Tier::Ram, b));
        assert!(tc.promote_secs(crate::memory::Tier::Ssd, b) > cm.transfer_secs(b));
    }

    #[test]
    fn exposed_transfer_clamps_at_zero() {
        assert_eq!(exposed_transfer_secs(1.0, 0.25), 0.75);
        assert_eq!(exposed_transfer_secs(1.0, 1.0), 0.0);
        assert_eq!(exposed_transfer_secs(1.0, 2.0), 0.0);
    }

    #[test]
    fn lead_layers_follow_the_tier_ladder() {
        use crate::memory::Tier;
        let cm = CostModel::paper_scale(66_048);
        let tc = cm.tier_costs();
        let b = cm.sim_expert_bytes;
        // device-resident: nothing to stage
        assert_eq!(lead_layers(&tc, Tier::Device, b, 4, 3), 0);
        // a RAM hop always fits inside one layer window
        for experts in [1, 2, 4, 8] {
            assert_eq!(lead_layers(&tc, Tier::Ram, b, experts, 3), 1);
        }
        // SSD-deep promotions (~9x the RAM hop) need 2-3 layers of lead
        // at typical per-layer expert counts, saturating the clamp when
        // layers are narrow
        for experts in [4, 8] {
            let lead = lead_layers(&tc, Tier::Ssd, b, experts, 3);
            assert!((2..=3).contains(&lead), "experts={experts} lead={lead}");
        }
        assert_eq!(lead_layers(&tc, Tier::Ssd, b, 1, 3), 3, "clamped at max_lead");
        // lead never exceeds the knob, never drops below 1 for off-device
        assert_eq!(lead_layers(&tc, Tier::Ssd, b, 4, 1), 1);
    }

    #[test]
    fn deadlines_scale_with_layers_ahead() {
        let cm = CostModel::paper_scale(66_048);
        let tc = cm.tier_costs();
        let b = cm.sim_expert_bytes;
        let w = layer_window_secs(&tc, b, 4);
        assert!((fetch_deadline_secs(&tc, b, 4, 1) - w).abs() < 1e-15);
        assert!((fetch_deadline_secs(&tc, b, 4, 3) - 3.0 * w).abs() < 1e-12);
        // the window is the layer's expert set over the PCIe hop
        assert!((w - 4.0 * cm.transfer_secs(b)).abs() < 1e-12);
    }
}
