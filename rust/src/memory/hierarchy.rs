//! Hierarchical offloading: device <-> host RAM <-> SSD (paper §6,
//! "Enhanced Hierarchical Offloading").
//!
//! The paper's discussion section proposes a third tier so models larger
//! than main memory (Switch-c-2048, ~5 TB) still serve: experts flow
//! device -> RAM -> SSD under per-tier byte budgets.  This module
//! implements the tier ladder as accounting + cost model (the physical
//! weights always live in the WeightStore blob; what moves is the
//! *residency level*, exactly like the device tier in `pool.rs`):
//!
//!   Device   budgeted; evictions demote to Ram
//!   Ram      budgeted; evictions demote to Ssd
//!   Ssd      unbounded backing store
//!
//! Fetch cost is the sum of the hops climbed (SSD->RAM ~2 GB/s NVMe,
//! RAM->device ~16 GB/s PCIe), so a hash-prefetched expert that was
//! demoted all the way to SSD costs ~9x a RAM-resident one — the
//! quantity the `ablation_hierarchy` comparison in `memory_budget`
//! exposes.

use std::collections::{HashMap, VecDeque};
use std::hash::Hash;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    Device,
    Ram,
    Ssd,
}

#[derive(Debug, Clone)]
pub struct TierCosts {
    /// RAM -> device bytes/sec (PCIe)
    pub pcie_bw: f64,
    pub pcie_latency: f64,
    /// SSD -> RAM bytes/sec (NVMe)
    pub ssd_bw: f64,
    pub ssd_latency: f64,
}

impl Default for TierCosts {
    fn default() -> Self {
        TierCosts {
            pcie_bw: 16.0e9,
            pcie_latency: 30.0e-6,
            ssd_bw: 2.0e9,
            ssd_latency: 100.0e-6,
        }
    }
}

impl TierCosts {
    /// Modeled seconds to promote `bytes` from `from` to Device.
    pub fn promote_secs(&self, from: Tier, bytes: usize) -> f64 {
        match from {
            Tier::Device => 0.0,
            Tier::Ram => self.pcie_latency + bytes as f64 / self.pcie_bw,
            Tier::Ssd => {
                self.ssd_latency
                    + bytes as f64 / self.ssd_bw
                    + self.pcie_latency
                    + bytes as f64 / self.pcie_bw
            }
        }
    }
}

#[derive(Debug, Default, Clone)]
pub struct HierarchyStats {
    pub device_hits: u64,
    pub ram_hits: u64,
    pub ssd_hits: u64,
    pub demotions_to_ram: u64,
    pub demotions_to_ssd: u64,
    pub modeled_promote_secs: f64,
}

/// FIFO-demoting three-tier residency ledger.
pub struct TieredStore<K: Eq + Hash + Clone + Copy> {
    device_budget: usize,
    ram_budget: usize,
    device_used: usize,
    ram_used: usize,
    tier_of: HashMap<K, (Tier, usize)>,
    device_fifo: VecDeque<K>,
    ram_fifo: VecDeque<K>,
    costs: TierCosts,
    pub stats: HierarchyStats,
}

impl<K: Eq + Hash + Clone + Copy> TieredStore<K> {
    pub fn new(device_budget: usize, ram_budget: usize, costs: TierCosts) -> Self {
        TieredStore {
            device_budget,
            ram_budget,
            device_used: 0,
            ram_used: 0,
            tier_of: HashMap::new(),
            device_fifo: VecDeque::new(),
            ram_fifo: VecDeque::new(),
            costs,
            stats: HierarchyStats::default(),
        }
    }

    pub fn tier(&self, key: &K) -> Tier {
        self.tier_of.get(key).map(|(t, _)| *t).unwrap_or(Tier::Ssd)
    }

    pub fn device_used(&self) -> usize {
        self.device_used
    }

    pub fn ram_used(&self) -> usize {
        self.ram_used
    }

    /// Bring `key` to the device tier, demoting FIFO victims down the
    /// ladder as needed.  Returns the modeled promote time.
    pub fn promote(&mut self, key: K, bytes: usize) -> f64 {
        let from = self.tier(&key);
        match from {
            Tier::Device => {
                self.stats.device_hits += 1;
                return 0.0;
            }
            Tier::Ram => {
                self.stats.ram_hits += 1;
                self.ram_used -= self.byte_of(&key);
                self.ram_fifo.retain(|k| k != &key);
            }
            Tier::Ssd => {
                self.stats.ssd_hits += 1;
            }
        }
        self.tier_of.remove(&key);
        // make room on device
        while self.device_used + bytes > self.device_budget {
            let Some(victim) = self.device_fifo.pop_front() else { break };
            let vb = self.byte_of_entry(&victim);
            self.device_used -= vb;
            self.tier_of.remove(&victim);
            self.demote_to_ram(victim, vb);
        }
        self.device_used += bytes;
        self.device_fifo.push_back(key);
        self.tier_of.insert(key, (Tier::Device, bytes));
        let secs = self.costs.promote_secs(from, bytes);
        self.stats.modeled_promote_secs += secs;
        secs
    }

    fn byte_of(&self, key: &K) -> usize {
        self.tier_of.get(key).map(|(_, b)| *b).unwrap_or(0)
    }

    fn byte_of_entry(&self, key: &K) -> usize {
        self.byte_of(key)
    }

    fn demote_to_ram(&mut self, key: K, bytes: usize) {
        self.stats.demotions_to_ram += 1;
        while self.ram_used + bytes > self.ram_budget {
            let Some(victim) = self.ram_fifo.pop_front() else { break };
            let vb = self.byte_of(&victim);
            self.ram_used -= vb;
            self.tier_of.remove(&victim);
            // falls to SSD (unbounded): just forget the residency record
            self.stats.demotions_to_ssd += 1;
        }
        if self.ram_used + bytes <= self.ram_budget {
            self.ram_used += bytes;
            self.ram_fifo.push_back(key);
            self.tier_of.insert(key, (Tier::Ram, bytes));
        } else {
            self.stats.demotions_to_ssd += 1;
        }
    }

    /// Consistency: tier accounting matches per-key records.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut dev = 0;
        let mut ram = 0;
        for (t, b) in self.tier_of.values() {
            match t {
                Tier::Device => dev += b,
                Tier::Ram => ram += b,
                Tier::Ssd => {}
            }
        }
        if dev != self.device_used {
            return Err(format!("device used {} != records {dev}", self.device_used));
        }
        if ram != self.ram_used {
            return Err(format!("ram used {} != records {ram}", self.ram_used));
        }
        if self.device_used > self.device_budget {
            return Err("device over budget".into());
        }
        if self.ram_used > self.ram_budget {
            return Err("ram over budget".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn promote_hits_tiers_in_order() {
        let mut s: TieredStore<u32> = TieredStore::new(100, 100, TierCosts::default());
        let t1 = s.promote(1, 60);
        assert!(t1 > 0.0); // came from SSD
        assert_eq!(s.tier(&1), Tier::Device);
        assert_eq!(s.promote(1, 60), 0.0); // device hit
        assert_eq!(s.stats.device_hits, 1);
    }

    #[test]
    fn eviction_cascades_down() {
        let mut s: TieredStore<u32> = TieredStore::new(100, 100, TierCosts::default());
        s.promote(1, 60);
        s.promote(2, 60); // evicts 1 -> RAM
        assert_eq!(s.tier(&1), Tier::Ram);
        assert_eq!(s.tier(&2), Tier::Device);
        s.promote(3, 60); // evicts 2 -> RAM, evicts 1 -> SSD
        assert_eq!(s.tier(&1), Tier::Ssd);
        assert_eq!(s.tier(&2), Tier::Ram);
        s.check_invariants().unwrap();
    }

    #[test]
    fn ram_hit_cheaper_than_ssd_hit() {
        let c = TierCosts::default();
        assert!(c.promote_secs(Tier::Ram, 1 << 20) < c.promote_secs(Tier::Ssd, 1 << 20));
        assert_eq!(c.promote_secs(Tier::Device, 1 << 20), 0.0);
    }

    #[test]
    fn promote_from_ram_counts_ram_hit() {
        let mut s: TieredStore<u32> = TieredStore::new(100, 100, TierCosts::default());
        s.promote(1, 60);
        s.promote(2, 60); // 1 demoted to RAM
        s.promote(1, 60); // RAM hit, 2 demoted
        assert_eq!(s.stats.ram_hits, 1);
        assert_eq!(s.tier(&1), Tier::Device);
        s.check_invariants().unwrap();
    }

    #[test]
    fn invariants_under_random_ops() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(9);
        let mut s: TieredStore<u32> = TieredStore::new(200, 150, TierCosts::default());
        for _ in 0..2000 {
            let key = rng.below(20) as u32;
            let bytes = 20 + rng.usize_below(60);
            s.promote(key, bytes);
            s.check_invariants().unwrap();
        }
        assert!(s.stats.demotions_to_ram > 0);
        assert!(s.stats.demotions_to_ssd > 0);
    }
}
