//! Hierarchical offloading: device <-> host RAM <-> SSD (paper §6,
//! "Enhanced Hierarchical Offloading").
//!
//! The paper's discussion section proposes a third tier so models larger
//! than main memory (Switch-c-2048, ~5 TB) still serve: experts flow
//! device -> RAM -> SSD under per-tier byte budgets.  This module is the
//! **single residency ledger** behind that ladder — one source of truth
//! for where every expert sits, *driven by* the expert cache rather than
//! modeled beside it:
//!
//!   Device   the cache's resident set, mirrored exactly (the cache owns
//!            the budget and the eviction policy; every eviction calls
//!            [`ResidencyLedger::demote`] with the policy-chosen victim)
//!   Ram      budgeted, with its **own** eviction policy
//!            (`--ram-policy`); overflow demotes to Ssd
//!   Ssd      unbounded backing store (the checkpoint); keys never seen
//!            by the ledger are Ssd-resident by definition
//!
//! A cache miss promotes the expert back to Device and is charged the
//! **tier-aware** ladder cost ([`TierCosts::promote_secs`]): a
//! RAM-resident expert pays one PCIe hop (numerically the cache's
//! historical H2D cost), an SSD-deep expert pays NVMe + PCIe (~9x).
//! Those seconds feed the cache's one modeled-transfer timeline (the
//! shared bandwidth window absorbs them); the ledger only *attributes*
//! the same seconds per source hop ([`HierarchyStats`]) — there is no
//! parallel promote clock to drift.
//!
//! The drift-proof invariant (property-tested for every eviction
//! policy): the ledger's Device tier is *exactly* the cache's resident
//! set, and tier byte sums are conserved across demote/promote.

use std::collections::{HashMap, HashSet};

use crate::experts::policy::EvictionPolicy;
use crate::experts::ExpertKey;

/// Default modeled host-RAM tier budget (simulated bytes, per cache):
/// roomy enough that single-device runs without `--ram-budget` keep the
/// historical "everything evicted stays one PCIe hop away" behavior.
/// Decimal 64 GB, matching the `--ram-budget 64` / `budget_gb * 1e9`
/// CLI convention exactly — every entry path builds the same window.
pub const DEFAULT_RAM_BUDGET: usize = 64_000_000_000;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Tier {
    Device,
    Ram,
    Ssd,
}

#[derive(Debug, Clone)]
pub struct TierCosts {
    /// RAM -> device bytes/sec (PCIe)
    pub pcie_bw: f64,
    pub pcie_latency: f64,
    /// SSD -> RAM bytes/sec (NVMe)
    pub ssd_bw: f64,
    pub ssd_latency: f64,
}

impl Default for TierCosts {
    fn default() -> Self {
        TierCosts {
            pcie_bw: 16.0e9,
            pcie_latency: 30.0e-6,
            ssd_bw: 2.0e9,
            ssd_latency: 100.0e-6,
        }
    }
}

impl TierCosts {
    /// Modeled seconds to promote `bytes` from `from` to Device.
    pub fn promote_secs(&self, from: Tier, bytes: usize) -> f64 {
        match from {
            Tier::Device => 0.0,
            Tier::Ram => self.pcie_latency + bytes as f64 / self.pcie_bw,
            Tier::Ssd => {
                self.ssd_latency
                    + bytes as f64 / self.ssd_bw
                    + self.pcie_latency
                    + bytes as f64 / self.pcie_bw
            }
        }
    }
}

/// Tier-ladder statistics: per-tier byte occupancy (snapshot), the
/// promotion/demotion traffic per hop, and the ladder seconds each
/// source tier charged onto the modeled-transfer timeline.
///
/// `ram_promote_secs + ssd_promote_secs` ([`HierarchyStats::ladder_secs`])
/// is the same quantity as the owning cache's miss-charged modeled
/// transfer seconds — attributed by source hop, not accounted twice.
#[derive(Debug, Default, Clone)]
pub struct HierarchyStats {
    /// simulated bytes resident per tier right now
    pub device_bytes: usize,
    pub ram_bytes: usize,
    pub ssd_bytes: usize,
    /// misses served one PCIe hop away (RAM-resident expert)
    pub promotions_from_ram: u64,
    /// misses that paid the full NVMe + PCIe ladder
    pub promotions_from_ssd: u64,
    /// device-tier evictions that landed in the RAM window
    pub demotions_to_ram: u64,
    /// demotions that fell through to SSD (RAM overflow, or the RAM
    /// window too small to ever hold the expert)
    pub demotions_to_ssd: u64,
    /// modeled seconds charged for RAM -> device promotions
    pub ram_promote_secs: f64,
    /// modeled seconds charged for SSD -> device promotions
    pub ssd_promote_secs: f64,
    /// **measured** wall seconds of verified on-disk blob reads (the
    /// real-I/O companion of `ssd_promote_secs`; zero without a store)
    pub measured_ssd_read_secs: f64,
    /// **measured** wall seconds of on-disk blob writes
    pub measured_ssd_write_secs: f64,
    /// bytes currently on disk in the expert store (du-style, distinct
    /// blobs counted once)
    pub store_bytes_on_disk: usize,
    /// blob verifications that failed (bad length/hash, or a verified
    /// payload the cache could not stage) — each fell back to bundle
    /// re-fabrication, never a wrong answer
    pub integrity_failures: u64,
    /// SSD promotions served by a verified on-disk read
    pub store_hits: u64,
    /// SSD promotions with no readable blob (never stored, reclaimed,
    /// or deleted underneath the manifest)
    pub store_misses: u64,
    /// SSD promotions that fell back to bundle re-fabrication
    /// (`store_misses` + failed verifications that re-fetched)
    pub refabrications: u64,
    /// blobs written to disk (demote spills + fabrication write-through)
    pub store_writes: u64,
    /// store entries reclaimed by the `--ssd-budget` bound
    pub store_reclaimed: u64,
}

impl HierarchyStats {
    /// Total ladder seconds charged onto the modeled-transfer timeline.
    pub fn ladder_secs(&self) -> f64 {
        self.ram_promote_secs + self.ssd_promote_secs
    }

    /// Fold another snapshot in (cluster aggregation over devices).
    pub fn add(&mut self, other: &HierarchyStats) {
        self.device_bytes += other.device_bytes;
        self.ram_bytes += other.ram_bytes;
        self.ssd_bytes += other.ssd_bytes;
        self.promotions_from_ram += other.promotions_from_ram;
        self.promotions_from_ssd += other.promotions_from_ssd;
        self.demotions_to_ram += other.demotions_to_ram;
        self.demotions_to_ssd += other.demotions_to_ssd;
        self.ram_promote_secs += other.ram_promote_secs;
        self.ssd_promote_secs += other.ssd_promote_secs;
        self.measured_ssd_read_secs += other.measured_ssd_read_secs;
        self.measured_ssd_write_secs += other.measured_ssd_write_secs;
        // NB: folding store occupancy is only double-count-free because
        // the on-disk store attaches to single-device serving (cluster
        // devices run store-less; see the pipeline wiring)
        self.store_bytes_on_disk += other.store_bytes_on_disk;
        self.integrity_failures += other.integrity_failures;
        self.store_hits += other.store_hits;
        self.store_misses += other.store_misses;
        self.refabrications += other.refabrications;
        self.store_writes += other.store_writes;
        self.store_reclaimed += other.store_reclaimed;
    }
}

/// The three-tier residency ledger one [`crate::experts::ExpertCache`]
/// owns (single-device serving and every cluster device share this one
/// mechanism).  The Device tier mirrors the cache exactly; the RAM tier
/// is budgeted with its own eviction policy; SSD is the unbounded
/// backing store.  See the module docs for the drive discipline.
pub struct ResidencyLedger {
    ram_budget: usize,
    ram_used: usize,
    ram_policy: Box<dyn EvictionPolicy>,
    ram: HashMap<ExpertKey, usize>,
    ssd: HashMap<ExpertKey, usize>,
    ssd_used: usize,
    device: HashMap<ExpertKey, usize>,
    device_used: usize,
    costs: TierCosts,
    /// ladder transits per key (lifetime demotions seen).  A victim
    /// tier has no hit stream of its own — entries are *inserted* on
    /// demote and *removed* on promote — so recency policies degenerate
    /// to insertion order (LRU == FIFO here, inherently).  What does
    /// carry signal is how often an expert transits the ladder: prior
    /// transits are replayed (capped) into the RAM policy as accesses on
    /// re-insert, so frequency/second-chance policies (lfu, clock)
    /// genuinely keep hot-transit experts one PCIe hop away.
    transits: HashMap<ExpertKey, u64>,
    /// counters only; occupancy is filled from live state at snapshot
    counters: HierarchyStats,
}

/// Cap on the transit-history replay per re-insert (bounds the per-
/// demote policy work while still separating hot from cold transits).
const TRANSIT_REPLAY_CAP: u64 = 7;

impl ResidencyLedger {
    pub fn new(ram_budget: usize, ram_policy: Box<dyn EvictionPolicy>, costs: TierCosts) -> Self {
        ResidencyLedger {
            ram_budget,
            ram_used: 0,
            ram_policy,
            ram: HashMap::new(),
            ssd: HashMap::new(),
            ssd_used: 0,
            device: HashMap::new(),
            device_used: 0,
            costs,
            transits: HashMap::new(),
            counters: HierarchyStats::default(),
        }
    }

    pub fn ram_budget(&self) -> usize {
        self.ram_budget
    }

    pub fn costs(&self) -> &TierCosts {
        &self.costs
    }

    /// Where `key` currently sits.  Keys the ledger has never seen live
    /// on SSD by definition (the checkpoint is the backing store).
    pub fn tier_of(&self, key: &ExpertKey) -> Tier {
        if self.device.contains_key(key) {
            Tier::Device
        } else if self.ram.contains_key(key) {
            Tier::Ram
        } else {
            Tier::Ssd
        }
    }

    /// Bring `key` to the Device tier (the cache just fetched it on a
    /// miss) and return the tier-aware modeled promote seconds — the
    /// cost the cache charges on its one modeled-transfer timeline.
    pub fn promote(&mut self, key: ExpertKey, bytes: usize) -> f64 {
        let from = self.tier_of(&key);
        match from {
            Tier::Device => return 0.0, // already mirrored; nothing to charge
            Tier::Ram => {
                let b = self.ram.remove(&key).unwrap_or(0);
                self.ram_used -= b;
                self.ram_policy.on_evict(key);
                self.counters.promotions_from_ram += 1;
            }
            Tier::Ssd => {
                if let Some(b) = self.ssd.remove(&key) {
                    self.ssd_used -= b;
                }
                self.counters.promotions_from_ssd += 1;
            }
        }
        let secs = self.costs.promote_secs(from, bytes);
        match from {
            Tier::Ram => self.counters.ram_promote_secs += secs,
            Tier::Ssd => self.counters.ssd_promote_secs += secs,
            Tier::Device => {}
        }
        self.device.insert(key, bytes);
        self.device_used += bytes;
        secs
    }

    /// Record a device-tier eviction of `key` (the cache's policy chose
    /// it as the victim, or it was explicitly invalidated): the expert
    /// demotes into the budgeted RAM window, cascading RAM victims —
    /// chosen by the RAM tier's own policy — down to SSD as needed.
    ///
    /// Returns every key that landed on the SSD tier during this call
    /// (the demoted key itself when it fell straight through, plus any
    /// cascaded RAM victims) — the cache's spill hook writes exactly
    /// these to the on-disk store, so blob writes track real SSD
    /// arrivals and nothing else.
    pub fn demote(&mut self, key: ExpertKey) -> Vec<ExpertKey> {
        let mut spilled = Vec::new();
        let Some(bytes) = self.device.remove(&key) else {
            return spilled; // never promoted through this ledger — nothing to move
        };
        self.device_used -= bytes;
        let prior_transits = {
            let t = self.transits.entry(key).or_insert(0);
            let prior = *t;
            *t += 1;
            prior
        };
        if bytes > self.ram_budget {
            // can never fit the RAM window: straight to SSD
            self.to_ssd(key, bytes, &mut spilled);
            return spilled;
        }
        let no_pins = HashSet::new();
        while self.ram_used + bytes > self.ram_budget {
            match self.ram_policy.victim(&no_pins) {
                Some(victim) => {
                    let vb = self.ram.remove(&victim).unwrap_or(0);
                    self.ram_used -= vb;
                    self.to_ssd(victim, vb, &mut spilled);
                }
                None => break, // RAM empty; the budget guard above ensures a fit
            }
        }
        if self.ram_used + bytes > self.ram_budget {
            // belt-and-braces: a policy that yielded no victim while the
            // window is over budget must not breach it
            self.to_ssd(key, bytes, &mut spilled);
            return spilled;
        }
        self.ram.insert(key, bytes);
        self.ram_used += bytes;
        self.ram_policy.on_insert(key);
        // replay the key's transit history as access standing (see the
        // `transits` field docs): hot-transit experts are worth keeping
        // in RAM under frequency/second-chance policies
        for _ in 0..prior_transits.min(TRANSIT_REPLAY_CAP) {
            self.ram_policy.on_access(key);
        }
        self.counters.demotions_to_ram += 1;
        spilled
    }

    fn to_ssd(&mut self, key: ExpertKey, bytes: usize, spilled: &mut Vec<ExpertKey>) {
        self.ssd.insert(key, bytes);
        self.ssd_used += bytes;
        self.counters.demotions_to_ssd += 1;
        spilled.push(key);
    }

    /// Pre-seed the SSD tier with a key known to be on disk (a reopened
    /// store's manifest).  Unseen keys are SSD by definition already;
    /// seeding records their byte occupancy so `ssd_bytes` reflects the
    /// warm store and promotion removes them tier-consistently.  No-op
    /// for keys the ledger already tracks anywhere.
    pub fn seed_ssd(&mut self, key: ExpertKey, bytes: usize) {
        if self.device.contains_key(&key) || self.ram.contains_key(&key) || self.ssd.contains_key(&key)
        {
            return;
        }
        self.ssd.insert(key, bytes);
        self.ssd_used += bytes;
    }

    /// Snapshot: counters plus the live per-tier occupancy.
    pub fn stats(&self) -> HierarchyStats {
        HierarchyStats {
            device_bytes: self.device_used,
            ram_bytes: self.ram_used,
            ssd_bytes: self.ssd_used,
            ..self.counters.clone()
        }
    }

    /// Zero the traffic counters (a new measurement epoch); residency —
    /// which tier every expert sits in — is state, not statistics, and
    /// carries over.
    pub fn reset_stats(&mut self) {
        self.counters = HierarchyStats::default();
    }

    /// Keys in the Device tier, sorted (the drift-check comparand).
    pub fn device_keys(&self) -> Vec<ExpertKey> {
        let mut keys: Vec<ExpertKey> = self.device.keys().copied().collect();
        keys.sort_unstable();
        keys
    }

    /// Total bytes the ledger tracks across all three tiers — constant
    /// across demote/promote once a key is known (conservation).
    pub fn tracked_bytes(&self) -> usize {
        self.device_used + self.ram_used + self.ssd_used
    }

    /// Internal consistency: per-tier accounting matches the per-key
    /// records, the tiers are disjoint, and RAM respects its budget.
    pub fn check_invariants(&self) -> Result<(), String> {
        let dev: usize = self.device.values().sum();
        if dev != self.device_used {
            return Err(format!("device used {} != records {dev}", self.device_used));
        }
        let ram: usize = self.ram.values().sum();
        if ram != self.ram_used {
            return Err(format!("ram used {} != records {ram}", self.ram_used));
        }
        let ssd: usize = self.ssd.values().sum();
        if ssd != self.ssd_used {
            return Err(format!("ssd used {} != records {ssd}", self.ssd_used));
        }
        if self.ram_used > self.ram_budget {
            return Err(format!(
                "ram over budget: {} > {}",
                self.ram_used, self.ram_budget
            ));
        }
        for key in self.device.keys() {
            if self.ram.contains_key(key) || self.ssd.contains_key(key) {
                return Err(format!("{key:?} resident in more than one tier"));
            }
        }
        for key in self.ram.keys() {
            if self.ssd.contains_key(key) {
                return Err(format!("{key:?} in both RAM and SSD"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experts::make_policy;

    fn k(e: usize) -> ExpertKey {
        ExpertKey::new(0, e)
    }

    fn ledger(ram_budget: usize) -> ResidencyLedger {
        ResidencyLedger::new(ram_budget, make_policy("fifo").unwrap(), TierCosts::default())
    }

    #[test]
    fn promote_costs_follow_the_ladder() {
        let c = TierCosts::default();
        let b = 1 << 20;
        assert_eq!(c.promote_secs(Tier::Device, b), 0.0);
        assert!(c.promote_secs(Tier::Ram, b) < c.promote_secs(Tier::Ssd, b));
        // the paper-scale expert: SSD-deep ≈ 9x a RAM-resident fetch
        let expert = 2 * 768 * 3072 * 4;
        let ratio = c.promote_secs(Tier::Ssd, expert) / c.promote_secs(Tier::Ram, expert);
        assert!(ratio > 7.0 && ratio < 11.0, "ladder ratio {ratio}");
    }

    #[test]
    fn unseen_keys_are_ssd_and_first_promote_pays_the_full_ladder() {
        let mut l = ledger(1000);
        assert_eq!(l.tier_of(&k(0)), Tier::Ssd);
        let secs = l.promote(k(0), 100);
        assert!((secs - l.costs().promote_secs(Tier::Ssd, 100)).abs() < 1e-15);
        assert_eq!(l.tier_of(&k(0)), Tier::Device);
        assert_eq!(l.stats().promotions_from_ssd, 1);
        l.check_invariants().unwrap();
    }

    #[test]
    fn demote_lands_in_ram_and_cascades_to_ssd() {
        let mut l = ledger(150);
        for e in 0..3 {
            l.promote(k(e), 100);
        }
        l.demote(k(0)); // -> RAM
        assert_eq!(l.tier_of(&k(0)), Tier::Ram);
        l.demote(k(1)); // RAM full -> 0 falls to SSD, 1 takes the window
        assert_eq!(l.tier_of(&k(0)), Tier::Ssd);
        assert_eq!(l.tier_of(&k(1)), Tier::Ram);
        let s = l.stats();
        assert_eq!(s.demotions_to_ram, 2);
        assert_eq!(s.demotions_to_ssd, 1);
        l.check_invariants().unwrap();
    }

    #[test]
    fn ram_promote_is_cheaper_than_ssd_promote() {
        let mut l = ledger(1000);
        l.promote(k(0), 100);
        l.demote(k(0));
        let from_ram = l.promote(k(0), 100);
        assert!((from_ram - l.costs().promote_secs(Tier::Ram, 100)).abs() < 1e-15);
        let from_ssd_cost = l.costs().promote_secs(Tier::Ssd, 100);
        assert!(from_ram < from_ssd_cost);
        let s = l.stats();
        assert_eq!(s.promotions_from_ram, 1);
        assert!((s.ladder_secs() - (s.ram_promote_secs + s.ssd_promote_secs)).abs() < 1e-15);
    }

    #[test]
    fn zero_ram_budget_sends_every_demotion_to_ssd() {
        let mut l = ledger(0);
        l.promote(k(0), 100);
        l.demote(k(0));
        assert_eq!(l.tier_of(&k(0)), Tier::Ssd);
        assert_eq!(l.stats().demotions_to_ram, 0);
        assert_eq!(l.stats().demotions_to_ssd, 1);
        l.check_invariants().unwrap();
    }

    #[test]
    fn ram_policy_knob_is_live_frequency_beats_insertion_order() {
        // The RAM window is a victim tier: entries insert on demote and
        // leave on promote, so pure recency degenerates to insertion
        // order (lru == fifo here, inherently).  The live signal is
        // ladder-transit frequency, replayed into the policy: under lfu
        // the twice-transited expert survives the overflow that costs
        // it the window under fifo — same trace, different victim.
        let run = |policy: &str| {
            let mut l =
                ResidencyLedger::new(250, make_policy(policy).unwrap(), TierCosts::default());
            for e in 0..3 {
                l.promote(k(e), 100);
            }
            l.demote(k(0)); // expert 0: transit 1
            l.promote(k(0), 100); // recalled from RAM (cheap PCIe hop)
            l.demote(k(0)); // expert 0: transit 2 -> access standing 2
            l.demote(k(1)); // expert 1: transit 1 -> access standing 1
            l.demote(k(2)); // overflow: the policy picks the victim
            l.check_invariants().unwrap();
            (l.tier_of(&k(0)), l.tier_of(&k(1)))
        };
        // lfu: the cold-transit expert 1 falls to SSD; hot 0 stays
        assert_eq!(run("lfu"), (Tier::Ram, Tier::Ssd));
        // fifo: insertion order alone — oldest insert (0) falls instead
        assert_eq!(run("fifo"), (Tier::Ssd, Tier::Ram));
    }

    #[test]
    fn tier_sums_are_conserved_across_demote_promote() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(11);
        let mut l = ledger(250);
        // make all 6 keys known (equal bytes)
        for e in 0..6 {
            l.promote(k(e), 100);
        }
        assert_eq!(l.tracked_bytes(), 600);
        for _ in 0..500 {
            let e = rng.usize_below(6);
            if rng.bool(0.5) {
                l.demote(k(e));
            } else if l.tier_of(&k(e)) != Tier::Device {
                l.promote(k(e), 100);
            }
            assert_eq!(l.tracked_bytes(), 600, "bytes leaked from the ladder");
            l.check_invariants().unwrap();
        }
        let s = l.stats();
        assert_eq!(s.device_bytes + s.ram_bytes + s.ssd_bytes, 600);
        assert!(s.demotions_to_ssd > 0, "250-byte RAM window must overflow");
    }

    #[test]
    fn ssd_exposure_is_monotone_in_ram_budget_for_fifo() {
        // the fig_hierarchy gate in miniature: replay one demote/promote
        // history against shrinking RAM windows; SSD promotions must not
        // decrease as the window shrinks (FIFO-with-deletion keeps the
        // smaller window's content a subset of the larger's)
        use crate::util::rng::Rng;
        let mut history: Vec<(bool, usize)> = Vec::new();
        let mut rng = Rng::new(3);
        for _ in 0..400 {
            history.push((rng.bool(0.5), rng.usize_below(8)));
        }
        let mut last_ssd = None;
        for ram_budget in [800usize, 400, 200, 100, 0] {
            let mut l = ledger(ram_budget);
            let mut on_device: HashSet<usize> = HashSet::new();
            for &(demote, e) in &history {
                if demote {
                    if on_device.remove(&e) {
                        l.demote(k(e));
                    }
                } else if on_device.insert(e) {
                    l.promote(k(e), 100);
                }
            }
            let ssd = l.stats().promotions_from_ssd;
            if let Some(prev) = last_ssd {
                assert!(
                    ssd >= prev,
                    "ram {ram_budget}: SSD promotions {ssd} fell below {prev}"
                );
            }
            last_ssd = Some(ssd);
        }
    }

    #[test]
    fn demote_reports_ssd_landings_and_seed_ssd_preserves_invariants() {
        let mut l = ledger(150);
        for e in 0..3 {
            l.promote(k(e), 100);
        }
        assert!(l.demote(k(0)).is_empty(), "RAM landing spills nothing");
        // RAM overflow: the cascaded victim (0) is reported, not key 1
        assert_eq!(l.demote(k(1)), vec![k(0)]);
        let mut l0 = ledger(0);
        l0.promote(k(5), 100);
        assert_eq!(l0.demote(k(5)), vec![k(5)], "straight-to-SSD reports the key itself");
        l0.seed_ssd(k(9), 40);
        assert_eq!(l0.tier_of(&k(9)), Tier::Ssd);
        assert_eq!(l0.stats().ssd_bytes, 140);
        l0.seed_ssd(k(5), 77); // already tracked: no-op
        assert_eq!(l0.stats().ssd_bytes, 140);
        l0.promote(k(9), 40); // seeded keys promote tier-consistently
        assert_eq!(l0.stats().ssd_bytes, 100);
        l0.check_invariants().unwrap();
    }

    #[test]
    fn reset_stats_keeps_residency() {
        let mut l = ledger(1000);
        l.promote(k(0), 100);
        l.demote(k(0));
        l.reset_stats();
        let s = l.stats();
        assert_eq!(s.demotions_to_ram, 0);
        assert_eq!(s.ladder_secs(), 0.0);
        // residency survived the epoch boundary
        assert_eq!(l.tier_of(&k(0)), Tier::Ram);
        assert_eq!(s.ram_bytes, 100);
    }
}
