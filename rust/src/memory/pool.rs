//! Device-memory pool: byte-budget accounting for the simulated GPU tier.
//!
//! Tracks which named regions (experts, dense weights, activations) are
//! resident and enforces the budget.  Pure accounting — the actual
//! staged PJRT buffers live in the expert cache; this type is the
//! invariant holder (`used <= budget`, reservation/release consistency)
//! and is what the property tests hammer.

use std::collections::HashMap;
use std::hash::Hash;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReserveOutcome {
    /// fitted within budget
    Ok,
    /// would exceed budget; nothing changed
    WouldExceed,
    /// already resident; refreshed only
    AlreadyResident,
}

#[derive(Debug)]
pub struct DevicePool<K: Eq + Hash + Clone> {
    budget: usize,
    used: usize,
    regions: HashMap<K, usize>,
    /// high-water mark of `used` (peak residency, Fig 8)
    peak: usize,
}

impl<K: Eq + Hash + Clone> DevicePool<K> {
    pub fn new(budget: usize) -> Self {
        DevicePool { budget, used: 0, regions: HashMap::new(), peak: 0 }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn used(&self) -> usize {
        self.used
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn free(&self) -> usize {
        self.budget.saturating_sub(self.used)
    }

    pub fn contains(&self, key: &K) -> bool {
        self.regions.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.regions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regions.is_empty()
    }

    pub fn bytes_of(&self, key: &K) -> Option<usize> {
        self.regions.get(key).copied()
    }

    /// Reserve `bytes` for `key`.  Fails (without side effects) if the
    /// budget would be exceeded; callers evict and retry.
    pub fn reserve(&mut self, key: K, bytes: usize) -> ReserveOutcome {
        if self.regions.contains_key(&key) {
            return ReserveOutcome::AlreadyResident;
        }
        if self.used + bytes > self.budget {
            return ReserveOutcome::WouldExceed;
        }
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        self.regions.insert(key, bytes);
        ReserveOutcome::Ok
    }

    /// Release a region; returns its size (0 if it was not resident).
    pub fn release(&mut self, key: &K) -> usize {
        match self.regions.remove(key) {
            Some(bytes) => {
                debug_assert!(self.used >= bytes);
                self.used -= bytes;
                bytes
            }
            None => 0,
        }
    }

    /// Would `bytes` more fit right now?
    pub fn fits(&self, bytes: usize) -> bool {
        self.used + bytes <= self.budget
    }

    pub fn keys(&self) -> impl Iterator<Item = &K> {
        self.regions.keys()
    }

    /// Reset peak tracking (per-benchmark-phase measurement).
    pub fn reset_peak(&mut self) {
        self.peak = self.used;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_and_release() {
        let mut p: DevicePool<u32> = DevicePool::new(100);
        assert_eq!(p.reserve(1, 60), ReserveOutcome::Ok);
        assert_eq!(p.reserve(2, 50), ReserveOutcome::WouldExceed);
        assert_eq!(p.used(), 60);
        assert_eq!(p.reserve(1, 60), ReserveOutcome::AlreadyResident);
        assert_eq!(p.release(&1), 60);
        assert_eq!(p.used(), 0);
        assert_eq!(p.release(&1), 0);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut p: DevicePool<u32> = DevicePool::new(100);
        p.reserve(1, 40);
        p.reserve(2, 40);
        p.release(&1);
        p.reserve(3, 10);
        assert_eq!(p.peak(), 80);
        assert_eq!(p.used(), 50);
        p.reset_peak();
        assert_eq!(p.peak(), 50);
    }

    #[test]
    fn exact_fill() {
        let mut p: DevicePool<&str> = DevicePool::new(10);
        assert_eq!(p.reserve("a", 10), ReserveOutcome::Ok);
        assert!(!p.fits(1));
        assert!(p.fits(0));
    }

    #[test]
    fn zero_budget_rejects_everything_nonzero() {
        let mut p: DevicePool<u32> = DevicePool::new(0);
        assert_eq!(p.reserve(1, 1), ReserveOutcome::WouldExceed);
        assert_eq!(p.reserve(2, 0), ReserveOutcome::Ok);
    }
}
