//! Simulated GPU-memory tier: byte-budget pool + transfer cost model.
//!
//! Compute in this repro runs for real on the PJRT CPU client; *memory
//! placement* is what we simulate (DESIGN.md §2, substitution table).
//! The pool enforces a device-byte budget at paper scale, and the cost
//! model charges modeled PCIe time for host->device expert movement —
//! exactly the cost SiDA's hash-prefetching removes from the critical
//! path.

pub mod cost;
pub mod hierarchy;
pub mod pool;
pub mod store;

pub use cost::{
    exposed_transfer_secs, fetch_deadline_secs, layer_window_secs, lead_layers, CostModel,
};
pub use hierarchy::{HierarchyStats, ResidencyLedger, Tier, TierCosts, DEFAULT_RAM_BUDGET};
pub use pool::{DevicePool, ReserveOutcome};
pub use store::{
    decode_expert_payload, encode_expert_payload, fnv1a64, ExpertStore, ReadOutcome, StoreStats,
    PAYLOAD_HEADER_BYTES,
};
