//! Topology descriptor: the Rust-side mirror of `model.json`.
//!
//! Everything the coordinator needs to drive the sliced artifacts —
//! dims, which blocks are MoE, expert counts, dataset profiles (static
//! sequence lengths) and the token buckets the per-expert artifact was
//! specialized for.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct HashTopo {
    pub hidden: usize,
    pub n_lstm_layers: usize,
    pub top_k: usize,
}

#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub moe_blocks: Vec<usize>,
    pub num_experts: usize,
    pub n_classes: usize,
    pub max_seq_len: usize,
    pub hash: HashTopo,
    /// dataset profile name -> static sequence length
    pub profiles: BTreeMap<String, usize>,
    /// token buckets for expert_T{bucket}.hlo.txt, ascending
    pub buckets: Vec<usize>,
    pub expert_param_bytes: usize,
    pub moe_param_bytes: usize,
    pub total_param_bytes: usize,
}

impl Topology {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("model.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing model.json")?;
        let hash = j.get("hash")?;
        let mut profiles = BTreeMap::new();
        for (k, v) in j.get("profiles")?.as_obj()? {
            profiles.insert(k.clone(), v.as_usize()?);
        }
        let mut buckets = j.get("buckets")?.usize_vec()?;
        buckets.sort_unstable();
        let topo = Topology {
            name: j.get_str("name")?.to_string(),
            vocab: j.get_usize("vocab")?,
            d_model: j.get_usize("d_model")?,
            d_ff: j.get_usize("d_ff")?,
            n_heads: j.get_usize("n_heads")?,
            n_blocks: j.get_usize("n_blocks")?,
            moe_blocks: j.get("moe_blocks")?.usize_vec()?,
            num_experts: j.get_usize("num_experts")?,
            n_classes: j.get_usize("n_classes")?,
            max_seq_len: j.get_usize("max_seq_len")?,
            hash: HashTopo {
                hidden: hash.get_usize("hidden")?,
                n_lstm_layers: hash.get_usize("n_lstm_layers")?,
                top_k: hash.get_usize("top_k")?,
            },
            profiles,
            buckets,
            expert_param_bytes: j.get_usize("expert_param_bytes")?,
            moe_param_bytes: j.get_usize("moe_param_bytes")?,
            total_param_bytes: j.get_usize("total_param_bytes")?,
        };
        if topo.buckets.is_empty() {
            bail!("model.json has no expert token buckets");
        }
        Ok(topo)
    }

    /// Number of MoE layers (M in the paper).
    pub fn num_moe_layers(&self) -> usize {
        self.moe_blocks.len()
    }

    /// MoE-layer ordinal of a block index, if it is a MoE block.
    pub fn moe_layer_index(&self, block: usize) -> Option<usize> {
        self.moe_blocks.iter().position(|&b| b == block)
    }

    /// Smallest bucket >= `count` (the largest bucket if count exceeds
    /// all — callers then split the token set into multiple calls).
    pub fn bucket_for(&self, count: usize) -> usize {
        for &b in &self.buckets {
            if b >= count {
                return b;
            }
        }
        *self.buckets.last().unwrap()
    }

    /// Sequence length for a dataset profile.
    pub fn seq_len(&self, profile: &str) -> Result<usize> {
        self.profiles
            .get(profile)
            .copied()
            .with_context(|| format!("unknown dataset profile '{profile}'"))
    }

    /// Total experts across all MoE layers.
    pub fn total_experts(&self) -> usize {
        self.num_moe_layers() * self.num_experts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn fake_topology_json() -> String {
        r#"{
            "name":"switch8","vocab":256,"d_model":64,"d_ff":128,
            "n_heads":4,"n_blocks":4,"moe_blocks":[1,3],"num_experts":8,
            "n_classes":4,"max_seq_len":256,
            "hash":{"hidden":48,"n_lstm_layers":2,"top_k":4},
            "profiles":{"sst2":32,"mrpc":96,"multirc":256},
            "buckets":[4,16,64,256],
            "expert_param_bytes":66048,"moe_param_bytes":1056768,
            "total_param_bytes":2000000
        }"#
        .to_string()
    }

    fn load_fake() -> Topology {
        let dir = std::env::temp_dir().join(format!("sida_topo_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("model.json"), fake_topology_json()).unwrap();
        let t = Topology::load(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        t
    }

    #[test]
    fn parses_fields() {
        let t = load_fake();
        assert_eq!(t.name, "switch8");
        assert_eq!(t.moe_blocks, vec![1, 3]);
        assert_eq!(t.num_moe_layers(), 2);
        assert_eq!(t.seq_len("sst2").unwrap(), 32);
        assert!(t.seq_len("unknown").is_err());
    }

    #[test]
    fn moe_layer_index() {
        let t = load_fake();
        assert_eq!(t.moe_layer_index(1), Some(0));
        assert_eq!(t.moe_layer_index(3), Some(1));
        assert_eq!(t.moe_layer_index(0), None);
    }

    #[test]
    fn bucket_selection() {
        let t = load_fake();
        assert_eq!(t.bucket_for(1), 4);
        assert_eq!(t.bucket_for(4), 4);
        assert_eq!(t.bucket_for(5), 16);
        assert_eq!(t.bucket_for(64), 64);
        assert_eq!(t.bucket_for(300), 256); // split case
    }
}
