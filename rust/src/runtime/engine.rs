//! PJRT engine: loads HLO-text artifacts, compiles them on the CPU
//! client, caches executables, and runs them.
//!
//! This is the only module that touches the `xla` crate's execution API.
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax>=0.5
//! serialized protos carry 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).
//!
//! ## Threading
//!
//! The wrapped `xla` types hold raw pointers and are `!Send`.  The PJRT
//! CPU client itself is thread-safe (its C++ implementation locks
//! internally and execution is re-entrant), and literals are plain host
//! buffers, so `Engine`/`Executable` are marked Send+Sync; the SiDA
//! pipeline relies on this to run the hash-building thread and the
//! inference thread concurrently over one client.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

/// A compiled serving entry point.
pub struct Executable {
    pub name: String,
    inner: xla::PjRtLoadedExecutable,
    /// cumulative dispatch statistics (hot-path profiling)
    pub stats: Mutex<ExecStats>,
}

#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

// SAFETY: see module docs — the PJRT CPU client is internally
// synchronized; executables and literals are usable from any thread as
// long as the client outlives them (guaranteed: Engine owns the client
// and executables hold a client refcount through the xla crate).
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with host literals; returns the flattened output tuple.
    /// Takes borrows — `execute` accepts `Borrow<Literal>`, so callers
    /// never clone weight literals onto the hot path (Literal::clone is
    /// a full host copy in the C++ wrapper).
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        log::trace!("exec {} ({} literal args)", self.name, args.len());
        let out = self
            .inner
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let result = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no output device", self.name))?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: empty output", self.name))?
            .to_literal_sync()?;
        // aot.py lowers everything with return_tuple=True
        let parts = result.to_tuple()?;
        let dt = t0.elapsed().as_secs_f64();
        let mut s = self.stats.lock().unwrap();
        s.calls += 1;
        s.total_secs += dt;
        Ok(parts)
    }

    /// Execute with pre-staged device buffers (the resident-expert path).
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let t0 = Instant::now();
        log::trace!("exec(b) {} ({} buffer args)", self.name, args.len());
        let out = self
            .inner
            .execute_b(args)
            .with_context(|| format!("executing(b) {}", self.name))?;
        let result = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: no output device", self.name))?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{}: empty output", self.name))?
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        let dt = t0.elapsed().as_secs_f64();
        let mut s = self.stats.lock().unwrap();
        s.calls += 1;
        s.total_secs += dt;
        Ok(parts)
    }

    pub fn snapshot_stats(&self) -> ExecStats {
        self.stats.lock().unwrap().clone()
    }
}

/// Device-buffer wrapper so staged expert weights can cross threads.
pub struct DeviceBuffer(pub xla::PjRtBuffer);

// SAFETY: same argument as Executable — PJRT CPU buffers are host memory
// managed by the internally-synchronized client.
unsafe impl Send for DeviceBuffer {}
unsafe impl Sync for DeviceBuffer {}

pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// cumulative compile statistics
    pub compile_stats: Mutex<ExecStats>,
}

unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        if !artifacts_dir.is_dir() {
            bail!(
                "artifacts dir {} not found — run `make artifacts` first",
                artifacts_dir.display()
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            compile_stats: Mutex::new(ExecStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load + compile `<entry>.hlo.txt`, memoized by entry name.
    pub fn load(&self, entry: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(entry) {
            return Ok(exe.clone());
        }
        let path = self.artifacts_dir.join(format!("{entry}.hlo.txt"));
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {entry}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut cs = self.compile_stats.lock().unwrap();
            cs.calls += 1;
            cs.total_secs += dt;
        }
        log::debug!("compiled {entry} in {dt:.3}s");
        let arc = Arc::new(Executable {
            name: entry.to_string(),
            inner: exe,
            stats: Mutex::new(ExecStats::default()),
        });
        self.cache.lock().unwrap().insert(entry.to_string(), arc.clone());
        Ok(arc)
    }

    /// Pre-compile a set of entries (pipeline warmup).
    pub fn preload(&self, entries: &[String]) -> Result<()> {
        for e in entries {
            self.load(e)?;
        }
        Ok(())
    }

    /// Stage host f32 data onto the device (the H2D transfer of the
    /// memory model; cost accounting lives in `memory::cost`).
    ///
    /// NOTE: this goes through `buffer_from_host_buffer`, whose C wrapper
    /// uses `kImmutableOnlyDuringCall` semantics (synchronous copy).  The
    /// literal-based `BufferFromHostLiteral` path is ASYNC in the PJRT
    /// CPU client — the literal must outlive the transfer, which a
    /// `stage(&temporary)` call pattern violates (observed as a
    /// `literal.size_bytes() == b->size()` CHECK crash).  Never stage
    /// from literals.
    /// (Also: only the *typed* `buffer_from_host_buffer::<T>` is safe —
    /// the crate's `buffer_from_host_raw_bytes` passes the ElementType
    /// ordinal where the C API expects a PrimitiveType, silently staging
    /// F32 data as F16.)
    pub fn stage_f32(&self, dims: &[usize], data: &[f32]) -> Result<DeviceBuffer> {
        Ok(DeviceBuffer(
            self.client.buffer_from_host_buffer(data, dims, None)?,
        ))
    }

    /// Stage i32 data (token ids).
    pub fn stage_i32(&self, dims: &[usize], data: &[i32]) -> Result<DeviceBuffer> {
        Ok(DeviceBuffer(
            self.client.buffer_from_host_buffer(data, dims, None)?,
        ))
    }

    /// Stage raw little-endian bytes with an explicit element type
    /// (weights straight out of the blob; see `stage_f32` for semantics).
    pub fn stage_raw(
        &self,
        ty: xla::ElementType,
        dims: &[usize],
        bytes: &[u8],
    ) -> Result<DeviceBuffer> {
        match ty {
            xla::ElementType::F32 => {
                debug_assert_eq!(bytes.as_ptr() as usize % 4, 0);
                let data = unsafe {
                    std::slice::from_raw_parts(bytes.as_ptr() as *const f32, bytes.len() / 4)
                };
                self.stage_f32(dims, data)
            }
            xla::ElementType::S32 => {
                debug_assert_eq!(bytes.as_ptr() as usize % 4, 0);
                let data = unsafe {
                    std::slice::from_raw_parts(bytes.as_ptr() as *const i32, bytes.len() / 4)
                };
                self.stage_i32(dims, data)
            }
            other => bail!("stage_raw: unsupported element type {other:?}"),
        }
    }

    /// Dispatch-time statistics across all cached executables.
    pub fn all_stats(&self) -> Vec<(String, ExecStats)> {
        self.cache
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot_stats()))
            .collect()
    }
}
