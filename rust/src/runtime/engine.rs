//! Execution engine: entry-point dispatch over a pluggable [`Backend`].
//!
//! Historically this module talked to PJRT directly; the backend trait
//! was extracted so the same `ModelRunner`/coordinator/server stack can
//! run on either implementation:
//!
//! * [`testkit::RefBackend`](crate::testkit) — a pure-Rust reference
//!   implementation of every serving entry point (`embed_L*`, `attn_L*`,
//!   `expert_T*`, `hash_L*`, ...), driven by the synthetic in-memory
//!   bundle.  This is what `cargo test` exercises hermetically: no
//!   Python, no artifacts, no native toolchain.
//! * `runtime::pjrt::PjrtBackend` (behind the `pjrt` cargo feature) —
//!   the original path that loads the HLO-text artifacts produced by
//!   `python/compile/aot.py` and executes them on the XLA CPU client.
//!   See DESIGN.md for how to vendor the `xla` crate and enable it.
//!
//! `Executable::run` keeps per-entry dispatch statistics either way, so
//! the hot-path profiling (`benches/hotpath.rs`) is backend-agnostic.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::runtime::tensor::{literal_f32, ElementType, Literal};

/// An execution backend: maps (entry name, literal args) -> output
/// literals.  Implementations must be internally synchronized — the SiDA
/// pipeline dispatches from the hash-building thread and the inference
/// thread concurrently.
pub trait Backend: Send + Sync {
    /// Human-readable platform name ("reference-cpu", "Host", ...).
    fn platform(&self) -> String;

    /// Prepare an entry for execution (compile/validate).  Called once
    /// per entry by `Engine::load`; the default is a no-op for backends
    /// with nothing to compile.
    fn prepare(&self, _entry: &str) -> Result<()> {
        Ok(())
    }

    /// Whether the per-sequence dense entries (`embed_L*`, `attn_L*`,
    /// `dense_ffn_L*`, `moe_ln_L*`, `moe_combine_L*`) accept a leading
    /// batch dimension `B > 1` (inputs shaped `[B, L, ...]` instead of
    /// `[1, L, ...]`).  The cross-request batched serving path uses this
    /// to collapse `B` dispatches into one; backends whose artifacts are
    /// specialized to batch 1 (the PJRT HLO path) keep the default and
    /// the batched forward falls back to per-request dense dispatch —
    /// expert invocations are still shared across the batch either way,
    /// because the `expert_T*` entries are shaped by token count, not by
    /// sequence.
    fn batched_entries(&self) -> bool {
        false
    }

    /// Execute one entry point.
    fn dispatch(&self, entry: &str, args: &[&Literal]) -> Result<Vec<Literal>>;
}

/// A loaded serving entry point, bound to its backend.
pub struct Executable {
    pub name: String,
    backend: Arc<dyn Backend>,
    /// cumulative dispatch statistics (hot-path profiling)
    pub stats: Mutex<ExecStats>,
}

#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub total_secs: f64,
}

impl Executable {
    /// Execute with host literals; returns the flattened output tuple.
    pub fn run(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        let t0 = Instant::now();
        log::trace!("exec {} ({} literal args)", self.name, args.len());
        let out = self.backend.dispatch(&self.name, args)?;
        let dt = t0.elapsed().as_secs_f64();
        let mut s = self.stats.lock().unwrap();
        s.calls += 1;
        s.total_secs += dt;
        Ok(out)
    }

    /// Execute with pre-staged device buffers (the resident-expert path).
    pub fn run_buffers(&self, args: &[&DeviceBuffer]) -> Result<Vec<Literal>> {
        let lits: Vec<&Literal> = args.iter().map(|b| &b.0).collect();
        self.run(&lits)
    }

    pub fn snapshot_stats(&self) -> ExecStats {
        self.stats.lock().unwrap().clone()
    }
}

/// A staged "device-resident" tensor.  On the reference backend the
/// device tier is simulated (budget + transfer-cost accounting live in
/// `memory::`), so residency is a host literal held by the expert cache;
/// under `pjrt` the literal is (re)staged onto the PJRT device at
/// dispatch time.
pub struct DeviceBuffer(pub Literal);

pub struct Engine {
    backend: Arc<dyn Backend>,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// cumulative compile/prepare statistics
    pub compile_stats: Mutex<ExecStats>,
}

impl Engine {
    /// Artifact-backed engine over `artifacts/<config>/` (the opt-in
    /// golden path).  Requires the `pjrt` feature; the default build has
    /// no HLO executor and reports how to get one.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        if !artifacts_dir.is_dir() {
            bail!(
                "artifacts dir {} not found — run `make artifacts` first",
                artifacts_dir.display()
            );
        }
        Self::artifact_backend(artifacts_dir)
    }

    #[cfg(feature = "pjrt")]
    fn artifact_backend(artifacts_dir: &Path) -> Result<Self> {
        let backend = Arc::new(crate::runtime::pjrt::PjrtBackend::new(artifacts_dir)?);
        Ok(Self::with_backend(backend, artifacts_dir))
    }

    #[cfg(not(feature = "pjrt"))]
    fn artifact_backend(_artifacts_dir: &Path) -> Result<Self> {
        bail!(
            "artifact execution requires the `pjrt` cargo feature \
             (cargo build --features pjrt after vendoring the xla crate; \
             see DESIGN.md); hermetic runs use the synthetic testkit bundle"
        )
    }

    /// Engine over an explicit backend (the testkit path).
    pub fn with_backend(backend: Arc<dyn Backend>, artifacts_dir: &Path) -> Self {
        Engine {
            backend,
            artifacts_dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            compile_stats: Mutex::new(ExecStats::default()),
        }
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// See [`Backend::batched_entries`].
    pub fn batched_entries(&self) -> bool {
        self.backend.batched_entries()
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load (prepare) one entry, memoized by entry name.
    pub fn load(&self, entry: &str) -> Result<Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(entry) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        self.backend.prepare(entry)?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut cs = self.compile_stats.lock().unwrap();
            cs.calls += 1;
            cs.total_secs += dt;
        }
        log::debug!("prepared {entry} in {dt:.3}s");
        let arc = Arc::new(Executable {
            name: entry.to_string(),
            backend: self.backend.clone(),
            stats: Mutex::new(ExecStats::default()),
        });
        self.cache.lock().unwrap().insert(entry.to_string(), arc.clone());
        Ok(arc)
    }

    /// Pre-compile a set of entries (pipeline warmup).
    pub fn preload(&self, entries: &[String]) -> Result<()> {
        for e in entries {
            self.load(e)?;
        }
        Ok(())
    }

    /// Stage host f32 data onto the (simulated) device — the H2D
    /// transfer of the memory model; cost accounting lives in
    /// `memory::cost`.
    pub fn stage_f32(&self, dims: &[usize], data: &[f32]) -> Result<DeviceBuffer> {
        Ok(DeviceBuffer(Literal::from_f32s(dims, data.to_vec())?))
    }

    /// Stage i32 data (token ids).
    pub fn stage_i32(&self, dims: &[usize], data: &[i32]) -> Result<DeviceBuffer> {
        Ok(DeviceBuffer(Literal::from_i32s(dims, data.to_vec())?))
    }

    /// Stage raw little-endian bytes with an explicit element type
    /// (weights straight out of the blob).
    pub fn stage_raw(
        &self,
        ty: ElementType,
        dims: &[usize],
        bytes: &[u8],
    ) -> Result<DeviceBuffer> {
        match ty {
            ElementType::F32 => Ok(DeviceBuffer(literal_f32(dims, bytes)?)),
            ElementType::S32 => {
                anyhow::ensure!(
                    bytes.len() % 4 == 0,
                    "i32 staging: byte length {} not a multiple of 4",
                    bytes.len()
                );
                let values: Vec<i32> = bytes
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Ok(DeviceBuffer(Literal::from_i32s(dims, values)?))
            }
        }
    }

    /// Dispatch-time statistics across all loaded executables.
    pub fn all_stats(&self) -> Vec<(String, ExecStats)> {
        self.cache
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot_stats()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy backend: "double_*" entries double their single f32 arg.
    struct Doubler;

    impl Backend for Doubler {
        fn platform(&self) -> String {
            "doubler".into()
        }

        fn dispatch(&self, entry: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
            anyhow::ensure!(entry.starts_with("double"), "unknown entry {entry}");
            let x = args[0].f32s()?;
            let y: Vec<f32> = x.iter().map(|v| v * 2.0).collect();
            Ok(vec![Literal::from_f32s(args[0].shape(), y)?])
        }
    }

    fn engine() -> Engine {
        Engine::with_backend(Arc::new(Doubler), Path::new("<test>"))
    }

    #[test]
    fn load_is_memoized_and_runs() {
        let eng = engine();
        let a = eng.load("double_x").unwrap();
        let b = eng.load("double_x").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let x = Literal::from_f32s(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let out = a.run(&[&x]).unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[2.0, 4.0, 6.0]);
        assert_eq!(a.snapshot_stats().calls, 1);
        assert_eq!(eng.all_stats().len(), 1);
    }

    #[test]
    fn run_buffers_equals_run() {
        let eng = engine();
        let exe = eng.load("double_y").unwrap();
        let buf = eng.stage_f32(&[2], &[1.5, -1.0]).unwrap();
        let out = exe.run_buffers(&[&buf]).unwrap();
        assert_eq!(out[0].f32s().unwrap(), &[3.0, -2.0]);
    }

    #[test]
    fn stage_raw_roundtrips() {
        let eng = engine();
        let mut bytes = Vec::new();
        for v in [4.0f32, 0.25] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let b = eng.stage_raw(ElementType::F32, &[2], &bytes).unwrap();
        assert_eq!(b.0.f32s().unwrap(), &[4.0, 0.25]);
        let mut ib = Vec::new();
        for v in [7i32, -9] {
            ib.extend_from_slice(&v.to_le_bytes());
        }
        let b = eng.stage_raw(ElementType::S32, &[2], &ib).unwrap();
        assert_eq!(b.0.i32s().unwrap(), &[7, -9]);
    }

    #[test]
    fn missing_artifacts_dir_is_an_error() {
        let err = Engine::new(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("not found"));
    }
}
