//! Runtime layer: PJRT engine, weight store, topology descriptor.
//!
//! `Engine` loads and executes the HLO-text artifacts produced by
//! `python/compile/aot.py`; `WeightStore` owns every tensor on the host;
//! `Topology` mirrors `model.json`.  Together they form a `ModelBundle`,
//! the unit the coordinator and all baselines operate on.

pub mod engine;
pub mod tensor;
pub mod topology;
pub mod weights;

pub use engine::{DeviceBuffer, Engine, ExecStats, Executable};
pub use tensor::{literal_from_f32s, literal_i32, to_f32_vec, to_i32_vec, Dtype, TensorMeta};
pub use topology::Topology;
pub use weights::WeightStore;

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

/// Stage one named weight tensor onto the device straight from the blob
/// (synchronous-copy semantics; see `Engine::stage_f32`).
pub fn stage_weight(
    engine: &Engine,
    weights: &WeightStore,
    name: &str,
) -> Result<DeviceBuffer> {
    let meta = weights.meta(name)?;
    engine.stage_raw(meta.dtype.element_type(), &meta.shape, weights.bytes(name)?)
}

/// Stage the four parts of one expert in artifact argument order.
pub fn stage_expert_parts(
    engine: &Engine,
    weights: &WeightStore,
    block: usize,
    expert: usize,
) -> Result<[DeviceBuffer; 4]> {
    let names = WeightStore::expert_part_names(block, expert);
    Ok([
        stage_weight(engine, weights, &names[0])?,
        stage_weight(engine, weights, &names[1])?,
        stage_weight(engine, weights, &names[2])?,
        stage_weight(engine, weights, &names[3])?,
    ])
}

/// Everything needed to serve one model config: compiled-artifact engine,
/// host weights, topology.
pub struct ModelBundle {
    pub engine: Arc<Engine>,
    pub weights: Arc<WeightStore>,
    pub topology: Arc<Topology>,
}

impl ModelBundle {
    /// Load from `artifacts/<config>/`.
    pub fn load(config_dir: &Path) -> Result<Self> {
        let engine = Arc::new(Engine::new(config_dir)?);
        let weights = Arc::new(WeightStore::load(config_dir)?);
        let topology = Arc::new(Topology::load(config_dir)?);
        Ok(ModelBundle { engine, weights, topology })
    }

    /// Conventional root: `artifacts/<name>` under the repo root.
    pub fn load_named(artifacts_root: &Path, name: &str) -> Result<Self> {
        Self::load(&artifacts_root.join(name))
    }
}
