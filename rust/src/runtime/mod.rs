//! Runtime layer: execution engine, weight store, topology descriptor.
//!
//! `Engine` dispatches serving entry points over a pluggable [`Backend`]
//! (the pure-Rust reference engine by default; PJRT over the HLO-text
//! artifacts produced by `python/compile/aot.py` behind the `pjrt`
//! feature); `WeightStore` owns every tensor on the host; `Topology`
//! mirrors `model.json`.  Together they form a `ModelBundle`, the unit
//! the coordinator and all baselines operate on.

pub mod engine;
pub mod pjrt;
pub mod tensor;
pub mod topology;
pub mod weights;

pub use engine::{Backend, DeviceBuffer, Engine, ExecStats, Executable};
pub use tensor::{
    literal_f32, literal_from_f32s, literal_i32, to_f32_vec, to_i32_vec, Dtype, ElementType,
    Literal, TensorMeta,
};
pub use topology::Topology;
pub use weights::WeightStore;

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

/// Stage one named weight tensor onto the device straight from the blob.
pub fn stage_weight(
    engine: &Engine,
    weights: &WeightStore,
    name: &str,
) -> Result<DeviceBuffer> {
    let meta = weights.meta(name)?;
    engine.stage_raw(meta.dtype.element_type(), &meta.shape, weights.bytes(name)?)
}

/// Stage the four parts of one expert in artifact argument order.
pub fn stage_expert_parts(
    engine: &Engine,
    weights: &WeightStore,
    block: usize,
    expert: usize,
) -> Result<[DeviceBuffer; 4]> {
    let names = WeightStore::expert_part_names(block, expert);
    Ok([
        stage_weight(engine, weights, &names[0])?,
        stage_weight(engine, weights, &names[1])?,
        stage_weight(engine, weights, &names[2])?,
        stage_weight(engine, weights, &names[3])?,
    ])
}

/// Stage one expert from a verified on-disk blob payload
/// ([`crate::memory::ExpertStore`]) instead of the host bundle.  Shapes
/// and dtypes still come from the manifest; every part length must
/// match its manifest byte count exactly, so a payload that decodes but
/// disagrees with the model is rejected (the cache counts that as an
/// integrity failure and re-fabricates).
pub fn stage_expert_parts_from_payload(
    engine: &Engine,
    weights: &WeightStore,
    block: usize,
    expert: usize,
    payload: &[u8],
) -> Result<[DeviceBuffer; 4]> {
    use anyhow::bail;
    let parts = crate::memory::decode_expert_payload(payload)?;
    let names = WeightStore::expert_part_names(block, expert);
    let mut staged: Vec<DeviceBuffer> = Vec::with_capacity(4);
    for (name, bytes) in names.iter().zip(parts.iter()) {
        let meta = weights.meta(name)?;
        if bytes.len() != meta.nbytes {
            bail!(
                "blob part '{name}' is {} bytes, manifest says {}",
                bytes.len(),
                meta.nbytes
            );
        }
        staged.push(engine.stage_raw(meta.dtype.element_type(), &meta.shape, bytes)?);
    }
    let mut it = staged.into_iter();
    Ok([
        it.next().unwrap(),
        it.next().unwrap(),
        it.next().unwrap(),
        it.next().unwrap(),
    ])
}

/// Everything needed to serve one model config: engine, host weights,
/// topology.
pub struct ModelBundle {
    pub engine: Arc<Engine>,
    pub weights: Arc<WeightStore>,
    pub topology: Arc<Topology>,
}

impl ModelBundle {
    /// Load from `artifacts/<config>/` (requires the `pjrt` feature for
    /// execution; see `testkit::bundle` for the hermetic synthetic path).
    pub fn load(config_dir: &Path) -> Result<Self> {
        let engine = Arc::new(Engine::new(config_dir)?);
        let weights = Arc::new(WeightStore::load(config_dir)?);
        let topology = Arc::new(Topology::load(config_dir)?);
        Ok(ModelBundle { engine, weights, topology })
    }

    /// Conventional root: `artifacts/<name>` under the repo root.
    pub fn load_named(artifacts_root: &Path, name: &str) -> Result<Self> {
        Self::load(&artifacts_root.join(name))
    }
}
