//! PJRT backend (opt-in, `--features pjrt`): loads HLO-text artifacts,
//! compiles them on the XLA CPU client, and runs them.
//!
//! This is the only module that touches the `xla` crate's execution API;
//! the rest of the system speaks `runtime::tensor::Literal` and reaches
//! execution through the [`Backend`](crate::runtime::engine::Backend)
//! trait.  Interchange is HLO *text* (`HloModuleProto::from_text_file`):
//! jax>=0.5 serialized protos carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! ## Threading
//!
//! The wrapped `xla` types hold raw pointers and are `!Send`.  The PJRT
//! CPU client itself is thread-safe (its C++ implementation locks
//! internally and execution is re-entrant), and literals are plain host
//! buffers, so the backend is marked Send+Sync; the SiDA pipeline relies
//! on this to run the hash-building thread and the inference thread
//! concurrently over one client.
//!
//! ## Staging semantics
//!
//! Host->device staging must go through the typed
//! `buffer_from_host_buffer::<T>` path, whose C wrapper uses
//! `kImmutableOnlyDuringCall` semantics (synchronous copy).  The
//! literal-based `BufferFromHostLiteral` path is ASYNC in the PJRT CPU
//! client — the literal must outlive the transfer, which a
//! `stage(&temporary)` call pattern violates (observed as a
//! `literal.size_bytes() == b->size()` CHECK crash).  Never stage from
//! literals.  (Also: the crate's `buffer_from_host_raw_bytes` passes the
//! ElementType ordinal where the C API expects a PrimitiveType, silently
//! staging F32 data as F16 — only the typed path is safe.)

#![cfg(feature = "pjrt")]

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use crate::runtime::engine::Backend;
use crate::runtime::tensor::{Dtype, Literal};

pub struct PjrtBackend {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    /// compiled entries behind Arc so dispatch can clone a handle out
    /// and release the map lock before executing — the hash-building
    /// and inference threads must overlap (see module docs)
    compiled: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

// SAFETY: see module docs — the PJRT CPU client is internally
// synchronized; executables and literals are usable from any thread as
// long as the client outlives them (guaranteed: the backend owns the
// client and executables hold a client refcount through the xla crate).
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtBackend {
            client,
            artifacts_dir: artifacts_dir.to_path_buf(),
            compiled: Mutex::new(HashMap::new()),
        })
    }

    fn to_xla(&self, lit: &Literal) -> Result<xla::Literal> {
        let shape = lit.shape();
        match lit.dtype() {
            Dtype::F32 => {
                let values = lit.f32s()?;
                let bytes: Vec<u8> =
                    values.iter().flat_map(|v| v.to_le_bytes()).collect();
                Ok(xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    &bytes,
                )?)
            }
            Dtype::I32 => {
                let values = lit.i32s()?;
                let bytes: Vec<u8> =
                    values.iter().flat_map(|v| v.to_le_bytes()).collect();
                Ok(xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    &bytes,
                )?)
            }
        }
    }

    fn from_xla(&self, lit: &xla::Literal) -> Result<Literal> {
        let shape: Vec<usize> = lit
            .shape()?
            .dimensions()
            .iter()
            .map(|&d| d as usize)
            .collect();
        match lit.element_type()? {
            xla::ElementType::F32 => Literal::from_f32s(&shape, lit.to_vec::<f32>()?),
            xla::ElementType::S32 => Literal::from_i32s(&shape, lit.to_vec::<i32>()?),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        }
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn prepare(&self, entry: &str) -> Result<()> {
        if self.compiled.lock().unwrap().contains_key(entry) {
            return Ok(());
        }
        let path = self.artifacts_dir.join(format!("{entry}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {entry}"))?;
        self.compiled.lock().unwrap().insert(entry.to_string(), Arc::new(exe));
        Ok(())
    }

    // NOTE: every dispatch converts its argument literals to
    // xla::Literals (a host copy).  The pre-trait engine cached weight
    // literals as xla::Literals inside ModelRunner/HashBuilder and
    // passed borrows; a backend-side conversion cache would restore
    // that — do it before using this backend for perf measurements.
    fn dispatch(&self, entry: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        self.prepare(entry)?;
        let xla_args: Vec<xla::Literal> = args
            .iter()
            .map(|a| self.to_xla(a))
            .collect::<Result<Vec<_>>>()?;
        let arg_refs: Vec<&xla::Literal> = xla_args.iter().collect();
        // clone the handle out and drop the lock: execution must not
        // serialize the hash-building and inference threads
        let exe = self
            .compiled
            .lock()
            .unwrap()
            .get(entry)
            .cloned()
            .ok_or_else(|| anyhow!("{entry}: vanished from compile cache"))?;
        let out = exe
            .execute::<&xla::Literal>(&arg_refs)
            .with_context(|| format!("executing {entry}"))?;
        let result = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{entry}: no output device"))?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{entry}: empty output"))?
            .to_literal_sync()?;
        // aot.py lowers everything with return_tuple=True
        let parts = result.to_tuple()?;
        parts.iter().map(|p| self.from_xla(p)).collect()
    }
}
