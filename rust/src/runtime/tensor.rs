//! Host tensor substrate: the `Literal` type every backend speaks.
//!
//! A `Literal` is a shaped, typed host buffer — the interchange unit
//! between the coordinator and an execution backend (`runtime::engine`).
//! The default build executes on the pure-Rust reference backend
//! (`testkit::RefBackend`), where literals ARE the device representation;
//! under the `pjrt` feature they are converted to `xla::Literal`s at the
//! dispatch boundary.

use crate::util::json::{Json, JsonError};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// Backend-facing element type (mirrors XLA's primitive-type naming).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self, JsonError> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => Err(JsonError::Type { wanted: "f32|i32", got: "other" }),
        }
    }

    pub fn size(&self) -> usize {
        4
    }

    pub fn element_type(&self) -> ElementType {
        match self {
            Dtype::F32 => ElementType::F32,
            Dtype::I32 => ElementType::S32,
        }
    }
}

/// Metadata record from manifest.json.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

impl TensorMeta {
    pub fn from_json(j: &Json) -> Result<Self, JsonError> {
        Ok(TensorMeta {
            name: j.get_str("name")?.to_string(),
            dtype: Dtype::parse(j.get_str("dtype")?)?,
            shape: j.get("shape")?.usize_vec()?,
            offset: j.get_usize("offset")?,
            nbytes: j.get_usize("nbytes")?,
        })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Typed payload of a literal.
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A shaped host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: Vec<usize>,
    data: LiteralData,
}

impl Literal {
    pub fn from_f32s(shape: &[usize], values: Vec<f32>) -> anyhow::Result<Self> {
        let want: usize = shape.iter().product();
        anyhow::ensure!(
            values.len() == want,
            "literal shape {shape:?} wants {want} elements, got {}",
            values.len()
        );
        Ok(Literal { shape: shape.to_vec(), data: LiteralData::F32(values) })
    }

    pub fn from_i32s(shape: &[usize], values: Vec<i32>) -> anyhow::Result<Self> {
        let want: usize = shape.iter().product();
        anyhow::ensure!(
            values.len() == want,
            "literal shape {shape:?} wants {want} elements, got {}",
            values.len()
        );
        Ok(Literal { shape: shape.to_vec(), data: LiteralData::I32(values) })
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            LiteralData::F32(_) => Dtype::F32,
            LiteralData::I32(_) => Dtype::I32,
        }
    }

    /// Borrow the f32 payload (error if i32-typed).
    pub fn f32s(&self) -> anyhow::Result<&[f32]> {
        match &self.data {
            LiteralData::F32(v) => Ok(v),
            LiteralData::I32(_) => anyhow::bail!("literal is i32, expected f32"),
        }
    }

    /// Borrow the i32 payload (error if f32-typed).
    pub fn i32s(&self) -> anyhow::Result<&[i32]> {
        match &self.data {
            LiteralData::I32(v) => Ok(v),
            LiteralData::F32(_) => anyhow::bail!("literal is f32, expected i32"),
        }
    }
}

/// Build an f32 literal from raw little-endian bytes (blob slices).
pub fn literal_f32(shape: &[usize], bytes: &[u8]) -> anyhow::Result<Literal> {
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "f32 literal byte length {} not a multiple of 4",
        bytes.len()
    );
    let values: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Literal::from_f32s(shape, values)
}

/// Build an i32 literal from host values.
pub fn literal_i32(shape: &[usize], values: &[i32]) -> anyhow::Result<Literal> {
    Literal::from_i32s(shape, values.to_vec())
}

/// Build an f32 literal from host values.
pub fn literal_from_f32s(shape: &[usize], values: &[f32]) -> anyhow::Result<Literal> {
    Literal::from_f32s(shape, values.to_vec())
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.f32s()?.to_vec())
}

/// Extract an i32 vector from a literal.
pub fn to_i32_vec(lit: &Literal) -> anyhow::Result<Vec<i32>> {
    Ok(lit.i32s()?.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("f64").is_err());
    }

    #[test]
    fn meta_from_json() {
        let j = Json::parse(
            r#"{"name":"w","dtype":"f32","shape":[2,3],"offset":64,"nbytes":24}"#,
        )
        .unwrap();
        let m = TensorMeta::from_json(&j).unwrap();
        assert_eq!(m.name, "w");
        assert_eq!(m.shape, vec![2, 3]);
        assert_eq!(m.element_count(), 6);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_from_f32s(&[2, 3], &vals).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vals);
        assert_eq!(lit.shape(), &[2, 3]);
        assert_eq!(lit.dtype(), Dtype::F32);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let vals = [7i32, -1, 0, 42];
        let lit = literal_i32(&[4], &vals).unwrap();
        assert_eq!(to_i32_vec(&lit).unwrap(), vals);
        assert!(to_f32_vec(&lit).is_err());
    }

    #[test]
    fn literal_from_le_bytes() {
        let mut bytes = Vec::new();
        for v in [0.5f32, -2.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let lit = literal_f32(&[2], &bytes).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![0.5, -2.0]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::from_f32s(&[2, 2], vec![1.0; 3]).is_err());
        assert!(Literal::from_i32s(&[5], vec![1; 4]).is_err());
    }
}
