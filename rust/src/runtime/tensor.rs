//! Host tensor views over the weight blob + conversion to XLA literals.

use crate::util::json::{Json, JsonError};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self, JsonError> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => Err(JsonError::Type { wanted: "f32|i32", got: "other" }),
        }
    }

    pub fn size(&self) -> usize {
        4
    }

    pub fn element_type(&self) -> xla::ElementType {
        match self {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::I32 => xla::ElementType::S32,
        }
    }
}

/// Metadata record from manifest.json.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

impl TensorMeta {
    pub fn from_json(j: &Json) -> Result<Self, JsonError> {
        Ok(TensorMeta {
            name: j.get_str("name")?.to_string(),
            dtype: Dtype::parse(j.get_str("dtype")?)?,
            shape: j.get("shape")?.usize_vec()?,
            offset: j.get_usize("offset")?,
            nbytes: j.get_usize("nbytes")?,
        })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Build an f32 literal from raw little-endian bytes.
pub fn literal_f32(shape: &[usize], bytes: &[u8]) -> anyhow::Result<xla::Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

/// Build an i32 literal from host values.
pub fn literal_i32(shape: &[usize], values: &[i32]) -> anyhow::Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )?)
}

/// Build an f32 literal from host values.
pub fn literal_from_f32s(shape: &[usize], values: &[f32]) -> anyhow::Result<xla::Literal> {
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len() * 4)
    };
    literal_f32(shape, bytes)
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract an i32 vector from a literal.
pub fn to_i32_vec(lit: &xla::Literal) -> anyhow::Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("i32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("f64").is_err());
    }

    #[test]
    fn meta_from_json() {
        let j = Json::parse(
            r#"{"name":"w","dtype":"f32","shape":[2,3],"offset":64,"nbytes":24}"#,
        )
        .unwrap();
        let m = TensorMeta::from_json(&j).unwrap();
        assert_eq!(m.name, "w");
        assert_eq!(m.shape, vec![2, 3]);
        assert_eq!(m.element_count(), 6);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = literal_from_f32s(&[2, 3], &vals).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vals);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let vals = [7i32, -1, 0, 42];
        let lit = literal_i32(&[4], &vals).unwrap();
        assert_eq!(to_i32_vec(&lit).unwrap(), vals);
    }
}
