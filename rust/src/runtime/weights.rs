//! Weight store: manifest.json + weights.bin reader.
//!
//! Loads the flat blob emitted by `python/compile/serialize.py` and
//! exposes tensors by name.  Expert tensors (`blocks.{b}.expert.{e}.w1`
//! etc.) are the unit of offloading: the store hands out *host literals*
//! on demand; tier placement (host RAM vs simulated device memory) is the
//! expert cache's job, not the store's.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::{literal_f32, Dtype, TensorMeta};
use crate::util::json::Json;

pub struct WeightStore {
    blob: Vec<u8>,
    metas: HashMap<String, TensorMeta>,
    pub total_bytes: usize,
}

impl WeightStore {
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let total_bytes = j.get_usize("total_bytes")?;
        let mut metas = HashMap::new();
        for t in j.get("tensors")?.as_arr()? {
            let m = TensorMeta::from_json(t)?;
            metas.insert(m.name.clone(), m);
        }
        let blob = std::fs::read(dir.join("weights.bin")).context("reading weights.bin")?;
        if blob.len() != total_bytes {
            bail!(
                "weights.bin size {} != manifest total_bytes {}",
                blob.len(),
                total_bytes
            );
        }
        Ok(WeightStore { blob, metas, total_bytes })
    }

    pub fn meta(&self, name: &str) -> Result<&TensorMeta> {
        self.metas
            .get(name)
            .with_context(|| format!("tensor '{name}' not in manifest"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.metas.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.metas.keys().map(|s| s.as_str())
    }

    pub fn bytes(&self, name: &str) -> Result<&[u8]> {
        let m = self.meta(name)?;
        Ok(&self.blob[m.offset..m.offset + m.nbytes])
    }

    /// View as f32 (alignment guaranteed: serializer aligns to 64 bytes).
    pub fn f32_slice(&self, name: &str) -> Result<&[f32]> {
        let m = self.meta(name)?;
        if m.dtype != Dtype::F32 {
            bail!("tensor '{name}' is not f32");
        }
        let bytes = &self.blob[m.offset..m.offset + m.nbytes];
        debug_assert_eq!(bytes.as_ptr() as usize % 4, 0);
        Ok(unsafe {
            std::slice::from_raw_parts(bytes.as_ptr() as *const f32, bytes.len() / 4)
        })
    }

    /// Materialize a host literal (one copy out of the blob).
    pub fn literal(&self, name: &str) -> Result<xla::Literal> {
        let m = self.meta(name)?;
        if m.dtype != Dtype::F32 {
            bail!("literal(): only f32 weights expected, got {name}");
        }
        literal_f32(&m.shape, self.bytes(name)?)
    }

    /// Bytes of one tensor (for memory accounting).
    pub fn nbytes(&self, name: &str) -> Result<usize> {
        Ok(self.meta(name)?.nbytes)
    }

    /// Sum of bytes across all tensors whose name starts with `prefix`
    /// (e.g. every expert of one layer, or the whole MoE share — Tab 2).
    pub fn bytes_with_prefix(&self, prefix: &str) -> usize {
        self.metas
            .values()
            .filter(|m| m.name.starts_with(prefix))
            .map(|m| m.nbytes)
            .sum()
    }

    /// Names of the four parts of one expert, in artifact argument order.
    pub fn expert_part_names(block: usize, expert: usize) -> [String; 4] {
        [
            format!("blocks.{block}.expert.{expert}.w1"),
            format!("blocks.{block}.expert.{expert}.b1"),
            format!("blocks.{block}.expert.{expert}.w2"),
            format!("blocks.{block}.expert.{expert}.b2"),
        ]
    }

    /// Total bytes of one expert (all four parts).
    pub fn expert_bytes(&self, block: usize, expert: usize) -> Result<usize> {
        let mut total = 0;
        for name in Self::expert_part_names(block, expert) {
            total += self.nbytes(&name)?;
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Build a tiny store on disk and read it back.
    fn fake_store(dir: &Path) {
        let t0: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let t1: Vec<f32> = vec![0.5; 16];
        let mut blob: Vec<u8> = Vec::new();
        for v in &t0 {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        while blob.len() % 64 != 0 {
            blob.push(0);
        }
        let off1 = blob.len();
        for v in &t1 {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::create_dir_all(dir).unwrap();
        std::fs::File::create(dir.join("weights.bin"))
            .unwrap()
            .write_all(&blob)
            .unwrap();
        let manifest = format!(
            r#"{{"version":1,"total_bytes":{},"tensors":[
                {{"name":"a","dtype":"f32","shape":[2,2],"offset":0,"nbytes":16}},
                {{"name":"blocks.0.expert.3.w1","dtype":"f32","shape":[4,4],"offset":{off1},"nbytes":64}}
            ]}}"#,
            blob.len()
        );
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn load_and_read() {
        let dir = std::env::temp_dir().join(format!("sida_ws_test_{}", std::process::id()));
        fake_store(&dir);
        let ws = WeightStore::load(&dir).unwrap();
        assert!(ws.has("a"));
        assert_eq!(ws.f32_slice("a").unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ws.meta("blocks.0.expert.3.w1").unwrap().shape, vec![4, 4]);
        assert_eq!(ws.bytes_with_prefix("blocks.0.expert."), 64);
        let lit = ws.literal("a").unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(ws.literal("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn expert_part_names_format() {
        let names = WeightStore::expert_part_names(1, 17);
        assert_eq!(names[0], "blocks.1.expert.17.w1");
        assert_eq!(names[3], "blocks.1.expert.17.b2");
    }
}
