//! Weight store: manifest + blob reader (disk or in-memory).
//!
//! Loads the flat blob emitted by `python/compile/serialize.py` — or one
//! fabricated by `testkit::synth` — and exposes tensors by name.  Expert
//! tensors (`blocks.{b}.expert.{e}.w1` etc.) are the unit of offloading:
//! the store hands out *host literals* on demand; tier placement (host
//! RAM vs simulated device memory) is the expert cache's job, not the
//! store's.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::{literal_f32, Dtype, Literal, TensorMeta};
use crate::util::json::Json;

/// 8-byte-aligned byte buffer so `f32_slice` views are always sound
/// (`Vec<u8>` alone only guarantees 1-byte alignment).
struct Blob {
    storage: Vec<u64>,
    len: usize,
}

impl Blob {
    fn from_bytes(bytes: &[u8]) -> Self {
        let words = bytes.len().div_ceil(8);
        let mut storage = vec![0u64; words];
        // SAFETY: u64 storage is at least bytes.len() long and any byte
        // pattern is a valid u64.
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                storage.as_mut_ptr() as *mut u8,
                bytes.len(),
            );
        }
        Blob { storage, len: bytes.len() }
    }

    fn bytes(&self) -> &[u8] {
        // SAFETY: storage holds at least `len` initialized bytes.
        unsafe { std::slice::from_raw_parts(self.storage.as_ptr() as *const u8, self.len) }
    }
}

pub struct WeightStore {
    blob: Blob,
    metas: HashMap<String, TensorMeta>,
    pub total_bytes: usize,
}

impl WeightStore {
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let total_bytes = j.get_usize("total_bytes")?;
        let mut metas = Vec::new();
        for t in j.get("tensors")?.as_arr()? {
            metas.push(TensorMeta::from_json(t)?);
        }
        let blob = std::fs::read(dir.join("weights.bin")).context("reading weights.bin")?;
        if blob.len() != total_bytes {
            bail!(
                "weights.bin size {} != manifest total_bytes {}",
                blob.len(),
                total_bytes
            );
        }
        Self::from_parts(&blob, metas)
    }

    /// Build from an in-memory blob + manifest (the testkit path).
    pub fn from_parts(blob: &[u8], metas: Vec<TensorMeta>) -> Result<Self> {
        let mut map = HashMap::new();
        for m in metas {
            if m.offset % 4 != 0 {
                bail!("tensor '{}' offset {} not 4-byte aligned", m.name, m.offset);
            }
            if m.offset + m.nbytes > blob.len() {
                bail!(
                    "tensor '{}' [{}, +{}) overruns blob of {} bytes",
                    m.name,
                    m.offset,
                    m.nbytes,
                    blob.len()
                );
            }
            map.insert(m.name.clone(), m);
        }
        Ok(WeightStore {
            blob: Blob::from_bytes(blob),
            metas: map,
            total_bytes: blob.len(),
        })
    }

    pub fn meta(&self, name: &str) -> Result<&TensorMeta> {
        self.metas
            .get(name)
            .with_context(|| format!("tensor '{name}' not in manifest"))
    }

    pub fn has(&self, name: &str) -> bool {
        self.metas.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.metas.keys().map(|s| s.as_str())
    }

    pub fn bytes(&self, name: &str) -> Result<&[u8]> {
        let m = self.meta(name)?;
        Ok(&self.blob.bytes()[m.offset..m.offset + m.nbytes])
    }

    /// View as f32 (alignment guaranteed: the blob storage is 8-byte
    /// aligned and `from_parts` rejects unaligned offsets).
    pub fn f32_slice(&self, name: &str) -> Result<&[f32]> {
        let m = self.meta(name)?;
        if m.dtype != Dtype::F32 {
            bail!("tensor '{name}' is not f32");
        }
        let bytes = &self.blob.bytes()[m.offset..m.offset + m.nbytes];
        debug_assert_eq!(bytes.as_ptr() as usize % 4, 0);
        // SAFETY: 4-byte-aligned (checked at construction), length is a
        // multiple of 4 by manifest construction, any bits are valid f32.
        Ok(unsafe {
            std::slice::from_raw_parts(bytes.as_ptr() as *const f32, bytes.len() / 4)
        })
    }

    /// Materialize a host literal (one copy out of the blob).
    pub fn literal(&self, name: &str) -> Result<Literal> {
        let m = self.meta(name)?;
        if m.dtype != Dtype::F32 {
            bail!("literal(): only f32 weights expected, got {name}");
        }
        literal_f32(&m.shape, self.bytes(name)?)
    }

    /// Bytes of one tensor (for memory accounting).
    pub fn nbytes(&self, name: &str) -> Result<usize> {
        Ok(self.meta(name)?.nbytes)
    }

    /// Sum of bytes across all tensors whose name starts with `prefix`
    /// (e.g. every expert of one layer, or the whole MoE share — Tab 2).
    pub fn bytes_with_prefix(&self, prefix: &str) -> usize {
        self.metas
            .values()
            .filter(|m| m.name.starts_with(prefix))
            .map(|m| m.nbytes)
            .sum()
    }

    /// Names of the four parts of one expert, in artifact argument order.
    pub fn expert_part_names(block: usize, expert: usize) -> [String; 4] {
        [
            format!("blocks.{block}.expert.{expert}.w1"),
            format!("blocks.{block}.expert.{expert}.b1"),
            format!("blocks.{block}.expert.{expert}.w2"),
            format!("blocks.{block}.expert.{expert}.b2"),
        ]
    }

    /// Total bytes of one expert (all four parts).
    pub fn expert_bytes(&self, block: usize, expert: usize) -> Result<usize> {
        let mut total = 0;
        for name in Self::expert_part_names(block, expert) {
            total += self.nbytes(&name)?;
        }
        Ok(total)
    }

    /// Serialize one expert into the on-disk blob payload of the §6 SSD
    /// tier ([`crate::memory::ExpertStore`]).  Built from the host blob
    /// — the authoritative copy every staging path reads — so a verified
    /// payload stages bit-identically to a direct bundle fetch.
    pub fn expert_payload(&self, block: usize, expert: usize) -> Result<Vec<u8>> {
        let names = Self::expert_part_names(block, expert);
        let parts: [&[u8]; 4] = [
            self.bytes(&names[0])?,
            self.bytes(&names[1])?,
            self.bytes(&names[2])?,
            self.bytes(&names[3])?,
        ];
        Ok(crate::memory::encode_expert_payload(&parts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn fake_blob() -> (Vec<u8>, String) {
        let t0: Vec<f32> = vec![1.0, 2.0, 3.0, 4.0];
        let t1: Vec<f32> = vec![0.5; 16];
        let mut blob: Vec<u8> = Vec::new();
        for v in &t0 {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        while blob.len() % 64 != 0 {
            blob.push(0);
        }
        let off1 = blob.len();
        for v in &t1 {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        let manifest = format!(
            r#"{{"version":1,"total_bytes":{},"tensors":[
                {{"name":"a","dtype":"f32","shape":[2,2],"offset":0,"nbytes":16}},
                {{"name":"blocks.0.expert.3.w1","dtype":"f32","shape":[4,4],"offset":{off1},"nbytes":64}}
            ]}}"#,
            blob.len()
        );
        (blob, manifest)
    }

    /// Build a tiny store on disk and read it back.
    fn fake_store(dir: &Path) {
        let (blob, manifest) = fake_blob();
        std::fs::create_dir_all(dir).unwrap();
        std::fs::File::create(dir.join("weights.bin"))
            .unwrap()
            .write_all(&blob)
            .unwrap();
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    }

    #[test]
    fn load_and_read() {
        let dir = std::env::temp_dir().join(format!("sida_ws_test_{}", std::process::id()));
        fake_store(&dir);
        let ws = WeightStore::load(&dir).unwrap();
        assert!(ws.has("a"));
        assert_eq!(ws.f32_slice("a").unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ws.meta("blocks.0.expert.3.w1").unwrap().shape, vec![4, 4]);
        assert_eq!(ws.bytes_with_prefix("blocks.0.expert."), 64);
        let lit = ws.literal("a").unwrap();
        assert_eq!(lit.f32s().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert!(ws.literal("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn from_parts_matches_disk_load() {
        let (blob, manifest) = fake_blob();
        let j = Json::parse(&manifest).unwrap();
        let metas: Vec<TensorMeta> = j
            .get("tensors")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| TensorMeta::from_json(t).unwrap())
            .collect();
        let ws = WeightStore::from_parts(&blob, metas).unwrap();
        assert_eq!(ws.f32_slice("a").unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ws.total_bytes, blob.len());
    }

    #[test]
    fn from_parts_rejects_overrun_and_misalignment() {
        let meta = |off: usize| TensorMeta {
            name: "x".into(),
            dtype: Dtype::F32,
            shape: vec![4],
            offset: off,
            nbytes: 16,
        };
        assert!(WeightStore::from_parts(&[0u8; 8], vec![meta(0)]).is_err());
        assert!(WeightStore::from_parts(&[0u8; 32], vec![meta(2)]).is_err());
        assert!(WeightStore::from_parts(&[0u8; 32], vec![meta(0)]).is_ok());
    }

    #[test]
    fn expert_part_names_format() {
        let names = WeightStore::expert_part_names(1, 17);
        assert_eq!(names[0], "blocks.1.expert.17.w1");
        assert_eq!(names[3], "blocks.1.expert.17.b2");
    }
}
