//! Serving configuration: JSON config files + CLI overrides.
//!
//! A config fully describes one serving run (model, dataset profile,
//! method, memory budget, workload).  `sida-moe serve --config x.json`
//! loads one; every field can be overridden on the command line.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// model config name (switch8|switch64|switch128|switch256)
    pub model: String,
    /// dataset profile (sst2|mrpc|multirc)
    pub dataset: String,
    /// serving method (sida|standard|deepspeed|tutel|layerwise|reactive)
    pub method: String,
    /// simulated device budget in GB (paper scale)
    pub budget_gb: f64,
    /// eviction policy for cached methods
    pub policy: String,
    /// modeled host-RAM tier budget in GB (`--ram-budget`): device
    /// evictions demote into this §6 ladder window; overflow falls to
    /// SSD.  Per device in cluster mode.
    pub ram_budget_gb: f64,
    /// the RAM window's own eviction policy (`--ram-policy`)
    pub ram_policy: String,
    /// on-disk expert store directory (`--store-dir`): SSD-tier
    /// promotions do real, hash-verified blob reads and demotions write
    /// blobs; reopening an existing directory pre-seeds the SSD tier so
    /// a restarted process serves warm.  Empty = modeled-only SSD tier.
    pub store_dir: String,
    /// byte budget of the on-disk store in GB (`--ssd-budget`, 0 =
    /// unbounded): overflow reclaims oldest-written blobs first
    pub ssd_budget_gb: f64,
    /// hash experts consumed per token (paper: 1 for sst2, 3 otherwise)
    pub k_used: usize,
    /// sleep modeled transfer cost on the critical path
    pub real_sleep: bool,
    /// run the prefetch stage of the SiDA pipeline
    pub prefetch: bool,
    /// how many MoE layers ahead the depth-window warmer may stage
    /// experts (`--prefetch-depth`; 1 = the one-layer-ahead baseline,
    /// 3 lets SSD-deep promotions start early enough to hide)
    pub prefetch_depth: usize,
    /// modeled host-link staging bandwidth in bytes/sec (`--host-bw`;
    /// 0 = the reference PCIe link) — scales the shared
    /// [`crate::experts::BandwidthWindow`] all prefetches contend on
    pub host_bw: f64,
    /// requests coalesced per forward pass (1 = the paper's batch-1
    /// setting; > 1 enables cross-request batching for the sida method)
    pub max_batch: usize,
    /// worker-pool width for concurrent expert execution (0 = auto-size
    /// from the machine / `SIDA_POOL_THREADS`; 1 = sequential)
    pub pool_threads: usize,
    /// modeled devices to serve across (1 = single device; > 1 enables
    /// expert parallelism for the sida method — the budget is then per
    /// device)
    pub devices: usize,
    /// hottest experts per MoE layer replicated across the fleet
    /// (cluster mode only)
    pub replicate_top: usize,
    /// availability floor: every predicted-hot expert placed on at
    /// least this many devices (`--min-replicas`; cluster mode only)
    pub min_replicas: usize,
    /// deterministic fault schedule on the batch-tick timeline
    /// (`--fault-plan`, e.g. `"down:1@8..24"`; cluster mode only,
    /// empty = fault-free)
    pub fault_plan: String,
    /// arrival process for the trace (`closed` replays the whole trace
    /// back-to-back; `poisson`/`bursty`/`diurnal` run the open-loop
    /// scheduler at `arrival_rate` — sida method only)
    pub arrivals: String,
    /// mean offered rate in requests/sec for open-loop arrivals
    pub arrival_rate: f64,
    /// fraction of trace requests on the interactive SLO lane
    pub interactive_frac: f64,
    /// interactive completion deadline in milliseconds
    pub slo_deadline_ms: f64,
    /// open-loop admission-queue bound
    pub queue_cap: usize,
    /// number of requests in the trace
    pub n_requests: usize,
    /// workload seed
    pub seed: u64,
    /// compute LM logits + NLL per request
    pub want_lm: bool,
    /// compute classifier logits per request
    pub want_cls: bool,
    /// artifacts root directory
    pub artifacts: String,
    /// write a Chrome trace-event JSON of the run here (`--trace-out`;
    /// empty = span tracer stays disabled, near-zero cost)
    pub trace_out: String,
    /// emit a one-line registry snapshot to stderr every this many
    /// seconds while serving (`--metrics-interval`; 0 = off)
    pub metrics_interval_secs: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "switch8".into(),
            dataset: "sst2".into(),
            method: "sida".into(),
            budget_gb: 8.0,
            policy: "fifo".into(),
            ram_budget_gb: 64.0,
            ram_policy: "fifo".into(),
            store_dir: String::new(),
            ssd_budget_gb: 0.0,
            k_used: 1,
            real_sleep: false,
            prefetch: true,
            prefetch_depth: 3,
            host_bw: 0.0,
            max_batch: 1,
            pool_threads: 0,
            devices: 1,
            replicate_top: 1,
            min_replicas: 1,
            fault_plan: String::new(),
            arrivals: "closed".into(),
            arrival_rate: 50.0,
            interactive_frac: 0.0,
            slo_deadline_ms: 100.0,
            queue_cap: 256,
            n_requests: 32,
            seed: 0,
            want_lm: false,
            want_cls: true,
            artifacts: "artifacts".into(),
            trace_out: String::new(),
            metrics_interval_secs: 0.0,
        }
    }
}

impl ServeConfig {
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = ServeConfig::default();
        let obj = j.as_obj()?;
        for (key, val) in obj {
            match key.as_str() {
                "model" => cfg.model = val.as_str()?.to_string(),
                "dataset" => cfg.dataset = val.as_str()?.to_string(),
                "method" => cfg.method = val.as_str()?.to_string(),
                "budget_gb" => cfg.budget_gb = val.as_f64()?,
                "policy" => cfg.policy = val.as_str()?.to_string(),
                "ram_budget_gb" => cfg.ram_budget_gb = val.as_f64()?,
                "ram_policy" => cfg.ram_policy = val.as_str()?.to_string(),
                "store_dir" => cfg.store_dir = val.as_str()?.to_string(),
                "ssd_budget_gb" => cfg.ssd_budget_gb = val.as_f64()?,
                "k_used" => cfg.k_used = val.as_usize()?,
                "real_sleep" => cfg.real_sleep = val.as_bool()?,
                "prefetch" => cfg.prefetch = val.as_bool()?,
                "prefetch_depth" => cfg.prefetch_depth = val.as_usize()?.max(1),
                "host_bw" => cfg.host_bw = val.as_f64()?.max(0.0),
                "max_batch" => cfg.max_batch = val.as_usize()?.max(1),
                "pool_threads" => cfg.pool_threads = val.as_usize()?,
                "devices" => cfg.devices = val.as_usize()?.max(1),
                "replicate_top" => cfg.replicate_top = val.as_usize()?,
                "min_replicas" => cfg.min_replicas = val.as_usize()?.max(1),
                "fault_plan" => cfg.fault_plan = val.as_str()?.to_string(),
                "arrivals" => cfg.arrivals = val.as_str()?.to_string(),
                "arrival_rate" => cfg.arrival_rate = val.as_f64()?,
                "interactive_frac" => cfg.interactive_frac = val.as_f64()?.clamp(0.0, 1.0),
                "slo_deadline_ms" => cfg.slo_deadline_ms = val.as_f64()?,
                "queue_cap" => cfg.queue_cap = val.as_usize()?.max(1),
                "n_requests" => cfg.n_requests = val.as_usize()?,
                "seed" => cfg.seed = val.as_u64()?,
                "want_lm" => cfg.want_lm = val.as_bool()?,
                "want_cls" => cfg.want_cls = val.as_bool()?,
                "artifacts" => cfg.artifacts = val.as_str()?.to_string(),
                "trace_out" => cfg.trace_out = val.as_str()?.to_string(),
                "metrics_interval_secs" => {
                    cfg.metrics_interval_secs = val.as_f64()?.max(0.0)
                }
                other => anyhow::bail!("unknown config key '{other}'"),
            }
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Apply CLI overrides (only keys present in `args`).
    pub fn apply_args(&mut self, args: &crate::util::cli::Args) {
        if let Some(v) = args.get("model") {
            self.model = v.to_string();
        }
        if let Some(v) = args.get("dataset") {
            self.dataset = v.to_string();
        }
        if let Some(v) = args.get("method") {
            self.method = v.to_string();
        }
        if let Some(v) = args.get("budget-gb") {
            if let Ok(x) = v.parse() {
                self.budget_gb = x;
            }
        }
        if let Some(v) = args.get("policy") {
            self.policy = v.to_string();
        }
        if let Some(v) = args.get("ram-budget") {
            if let Ok(x) = v.parse() {
                self.ram_budget_gb = x;
            }
        }
        if let Some(v) = args.get("ram-policy") {
            self.ram_policy = v.to_string();
        }
        if let Some(v) = args.get("store-dir") {
            self.store_dir = v.to_string();
        }
        if let Some(v) = args.get("ssd-budget") {
            if let Ok(x) = v.parse() {
                self.ssd_budget_gb = x;
            }
        }
        if let Some(v) = args.get("k-used") {
            if let Ok(x) = v.parse() {
                self.k_used = x;
            }
        }
        if let Some(v) = args.get("prefetch-depth") {
            if let Ok(x) = v.parse::<usize>() {
                self.prefetch_depth = x.max(1);
            }
        }
        if let Some(v) = args.get("host-bw") {
            if let Ok(x) = v.parse::<f64>() {
                self.host_bw = x.max(0.0);
            }
        }
        if let Some(v) = args.get("batch") {
            if let Ok(x) = v.parse::<usize>() {
                self.max_batch = x.max(1);
            }
        }
        if let Some(v) = args.get("pool") {
            if let Ok(x) = v.parse::<usize>() {
                self.pool_threads = x;
            }
        }
        if let Some(v) = args.get("devices") {
            if let Ok(x) = v.parse::<usize>() {
                self.devices = x.max(1);
            }
        }
        if let Some(v) = args.get("replicate-top") {
            if let Ok(x) = v.parse::<usize>() {
                self.replicate_top = x;
            }
        }
        if let Some(v) = args.get("min-replicas") {
            if let Ok(x) = v.parse::<usize>() {
                self.min_replicas = x.max(1);
            }
        }
        if let Some(v) = args.get("fault-plan") {
            self.fault_plan = v.to_string();
        }
        if let Some(v) = args.get("arrivals") {
            self.arrivals = v.to_string();
        }
        if let Some(v) = args.get("rate") {
            if let Ok(x) = v.parse() {
                self.arrival_rate = x;
            }
        }
        if let Some(v) = args.get("interactive-frac") {
            if let Ok(x) = v.parse::<f64>() {
                self.interactive_frac = x.clamp(0.0, 1.0);
            }
        }
        if let Some(v) = args.get("slo-deadline") {
            if let Ok(x) = v.parse() {
                self.slo_deadline_ms = x;
            }
        }
        if let Some(v) = args.get("queue-cap") {
            if let Ok(x) = v.parse::<usize>() {
                self.queue_cap = x.max(1);
            }
        }
        if let Some(v) = args.get("requests") {
            if let Ok(x) = v.parse() {
                self.n_requests = x;
            }
        }
        if let Some(v) = args.get("seed") {
            if let Ok(x) = v.parse() {
                self.seed = x;
            }
        }
        if let Some(v) = args.get("artifacts") {
            self.artifacts = v.to_string();
        }
        if let Some(v) = args.get("trace-out") {
            self.trace_out = v.to_string();
        }
        if let Some(v) = args.get("metrics-interval") {
            if let Ok(x) = v.parse::<f64>() {
                self.metrics_interval_secs = x.max(0.0);
            }
        }
        if args.flag("real-sleep") {
            self.real_sleep = true;
        }
        if args.flag("no-prefetch") {
            self.prefetch = false;
        }
        if args.flag("lm") {
            self.want_lm = true;
        }
    }

    pub fn budget_bytes(&self) -> usize {
        (self.budget_gb * 1e9) as usize
    }

    pub fn ram_budget_bytes(&self) -> usize {
        (self.ram_budget_gb * 1e9) as usize
    }

    /// On-disk store budget in bytes (0 = unbounded).
    pub fn ssd_budget_bytes(&self) -> usize {
        (self.ssd_budget_gb * 1e9) as usize
    }

    /// The paper's per-dataset k: top-1 for SST2, top-3 for MRPC/MultiRC.
    pub fn paper_k_for(dataset: &str) -> usize {
        if dataset == "sst2" {
            1
        } else {
            3
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let j = Json::parse(
            r#"{"model":"switch128","dataset":"mrpc","method":"standard",
                "budget_gb":24.5,"policy":"lru","k_used":3,"real_sleep":true,
                "prefetch":false,"max_batch":8,"n_requests":64,"seed":7,
                "want_lm":true,"want_cls":false,"artifacts":"a"}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "switch128");
        assert_eq!(c.k_used, 3);
        assert_eq!(c.max_batch, 8);
        assert!((c.budget_gb - 24.5).abs() < 1e-9);
        assert!(c.real_sleep);
        assert!(!c.prefetch);
    }

    #[test]
    fn cluster_keys_parse_and_clamp() {
        let j = Json::parse(r#"{"devices":4,"replicate_top":2}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.devices, 4);
        assert_eq!(c.replicate_top, 2);
        let j = Json::parse(r#"{"devices":0}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().devices, 1);
        // defaults: single device, one replica slot
        let d = ServeConfig::default();
        assert_eq!(d.devices, 1);
        assert_eq!(d.replicate_top, 1);
    }

    #[test]
    fn fault_keys_parse_and_clamp() {
        let j = Json::parse(r#"{"min_replicas":2,"fault_plan":"down:1@8..24"}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.min_replicas, 2);
        assert_eq!(c.fault_plan, "down:1@8..24");
        let j = Json::parse(r#"{"min_replicas":0}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().min_replicas, 1);
        // defaults: no availability floor beyond one holder, fault-free
        let d = ServeConfig::default();
        assert_eq!(d.min_replicas, 1);
        assert!(d.fault_plan.is_empty());
    }

    #[test]
    fn ram_tier_keys_parse_with_defaults() {
        let j = Json::parse(r#"{"ram_budget_gb":2.5,"ram_policy":"lru"}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert!((c.ram_budget_gb - 2.5).abs() < 1e-9);
        assert_eq!(c.ram_policy, "lru");
        assert_eq!(c.ram_budget_bytes(), 2_500_000_000);
        let d = ServeConfig::default();
        assert!((d.ram_budget_gb - 64.0).abs() < 1e-9);
        assert_eq!(d.ram_policy, "fifo");
    }

    #[test]
    fn store_keys_parse_with_defaults() {
        let j = Json::parse(r#"{"store_dir":"/tmp/sida-store","ssd_budget_gb":0.5}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.store_dir, "/tmp/sida-store");
        assert_eq!(c.ssd_budget_bytes(), 500_000_000);
        let d = ServeConfig::default();
        assert!(d.store_dir.is_empty(), "modeled-only SSD tier by default");
        assert_eq!(d.ssd_budget_bytes(), 0, "0 = unbounded");
    }

    #[test]
    fn prefetch_scheduler_keys_parse_and_clamp() {
        let j = Json::parse(r#"{"prefetch_depth":4,"host_bw":8e9}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.prefetch_depth, 4);
        assert!((c.host_bw - 8e9).abs() < 1.0);
        // clamps: depth floors at the one-layer-ahead baseline,
        // negative bandwidth means "reference link"
        let j = Json::parse(r#"{"prefetch_depth":0,"host_bw":-1}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.prefetch_depth, 1);
        assert_eq!(c.host_bw, 0.0);
        let d = ServeConfig::default();
        assert_eq!(d.prefetch_depth, 3);
        assert_eq!(d.host_bw, 0.0);
    }

    #[test]
    fn max_batch_clamped_to_one() {
        let j = Json::parse(r#"{"max_batch":0}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.max_batch, 1);
    }

    #[test]
    fn slo_keys_parse_with_defaults() {
        let j = Json::parse(
            r#"{"arrivals":"bursty","arrival_rate":120.0,"interactive_frac":1.5,
                "slo_deadline_ms":40.0,"queue_cap":0}"#,
        )
        .unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.arrivals, "bursty");
        assert!((c.arrival_rate - 120.0).abs() < 1e-9);
        assert_eq!(c.interactive_frac, 1.0, "fraction clamps to [0,1]");
        assert!((c.slo_deadline_ms - 40.0).abs() < 1e-9);
        assert_eq!(c.queue_cap, 1, "queue cap clamps to >= 1");
        let d = ServeConfig::default();
        assert_eq!(d.arrivals, "closed");
        assert_eq!(d.interactive_frac, 0.0);
        assert_eq!(d.queue_cap, 256);
    }

    #[test]
    fn observability_keys_parse_with_defaults() {
        let j =
            Json::parse(r#"{"trace_out":"/tmp/t.json","metrics_interval_secs":5.0}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.trace_out, "/tmp/t.json");
        assert!((c.metrics_interval_secs - 5.0).abs() < 1e-9);
        let j = Json::parse(r#"{"metrics_interval_secs":-1.0}"#).unwrap();
        assert_eq!(ServeConfig::from_json(&j).unwrap().metrics_interval_secs, 0.0);
        let d = ServeConfig::default();
        assert!(d.trace_out.is_empty(), "tracer off by default");
        assert_eq!(d.metrics_interval_secs, 0.0, "no periodic snapshot by default");
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"modell":"x"}"#).unwrap();
        assert!(ServeConfig::from_json(&j).is_err());
    }

    #[test]
    fn defaults_fill_missing() {
        let j = Json::parse(r#"{"model":"switch64"}"#).unwrap();
        let c = ServeConfig::from_json(&j).unwrap();
        assert_eq!(c.model, "switch64");
        assert_eq!(c.dataset, "sst2");
        assert_eq!(c.policy, "fifo");
    }

    #[test]
    fn paper_k() {
        assert_eq!(ServeConfig::paper_k_for("sst2"), 1);
        assert_eq!(ServeConfig::paper_k_for("mrpc"), 3);
        assert_eq!(ServeConfig::paper_k_for("multirc"), 3);
    }

    #[test]
    fn budget_bytes_conversion() {
        let mut c = ServeConfig::default();
        c.budget_gb = 2.0;
        assert_eq!(c.budget_bytes(), 2_000_000_000);
    }
}
