//! Model orchestration over sliced artifacts.
//!
//! `ModelRunner` drives one model config at one dataset profile
//! (sequence length), calling the shape-specialized artifacts in order:
//! embed -> attn -> ffn (repeated per block) -> heads.  MoE FFN layers
//! are dispatched per expert; *who* provides the expert weights
//! (all-resident buffers, the SiDA cache, or plain host literals) is
//! abstracted by [`ExpertProvider`], which is what separates SiDA from
//! the baselines.
//!
//! Two forward entry points exist: [`ModelRunner::forward`] serves one
//! sentence (the paper's batch-1 setting), and
//! [`ModelRunner::forward_batch`] serves a cross-request batch in which
//! every MoE layer issues **one expert invocation per activated expert
//! across the whole batch** — bit-identical outputs, amortized expert
//! traffic.

pub mod forward;

pub use forward::{
    BatchForwardOutput, BatchItem, ExpertProvider, ForwardHooks, ForwardOptions, ForwardOutput,
    ModelRunner, PhaseTimes, RoutingDecision,
};
