//! Model orchestration over sliced artifacts.
//!
//! `ModelRunner` drives one model config at one dataset profile
//! (sequence length), calling the shape-specialized artifacts in order:
//! embed -> [attn -> ffn]* -> heads.  MoE FFN layers are dispatched
//! per expert; *who* provides the expert weights (all-resident buffers,
//! the SiDA cache, or plain host literals) is abstracted by
//! [`ExpertProvider`], which is what separates SiDA from the baselines.

pub mod forward;

pub use forward::{ExpertProvider, ForwardOptions, ForwardOutput, ModelRunner, PhaseTimes, RoutingDecision};
