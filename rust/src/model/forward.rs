//! Forward-pass orchestration: the Rust twin of `python/compile/model.py`.
//!
//! The paper evaluates at batch size 1 (§2: "all experiments are
//! conducted with a batch size of 1 to isolate the influence of batch
//! size") — [`ModelRunner::forward`], where a sequence of L tokens
//! flows through artifacts specialized to `[1, L]`.
//! [`ModelRunner::forward_batch`] extends the same arithmetic to
//! cross-request batches: dense stages per request (or stacked, when
//! the backend supports batched entries), expert dispatch shared across
//! the batch, outputs bit-identical to sequential forwards.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::hash_table::HashTable;
use crate::experts::{ExpertCache, ExpertKey};
use crate::runtime::{
    literal_from_f32s, literal_i32, to_f32_vec, to_i32_vec, DeviceBuffer, Executable, Literal,
    ModelBundle,
};

/// Wall-time breakdown of one forward pass (Fig 3's phases).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    /// embed + attention + dense FFN + heads — the paper's "ideal
    /// inference time"
    pub dense_secs: f64,
    /// router execution (baselines) or hash-table wait (SiDA)
    pub selection_secs: f64,
    /// per-expert dispatch + compute
    pub expert_secs: f64,
    /// modeled H2D transfer time charged on the critical path
    pub transfer_secs: f64,
    /// number of expert invocations issued
    pub expert_invocations: u64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.dense_secs + self.selection_secs + self.expert_secs + self.transfer_secs
    }

    pub fn moe_overhead(&self) -> f64 {
        self.selection_secs + self.expert_secs + self.transfer_secs
    }

    pub fn add(&mut self, other: &PhaseTimes) {
        self.dense_secs += other.dense_secs;
        self.selection_secs += other.selection_secs;
        self.expert_secs += other.expert_secs;
        self.transfer_secs += other.transfer_secs;
        self.expert_invocations += other.expert_invocations;
    }
}

/// Per-MoE-layer routing decision: for each token, the experts that
/// compute it and their (renormalized) combine weights.
#[derive(Debug, Clone)]
pub struct RoutingDecision {
    /// `[L]` primary expert per token (rank 0)
    pub top1: Vec<usize>,
    /// token -> [(expert, alpha)] for k_used experts
    pub assignments: Vec<Vec<(usize, f32)>>,
}

impl RoutingDecision {
    /// Unique experts used, ascending.
    pub fn active_experts(&self, mask: &[f32]) -> Vec<usize> {
        let mut set: Vec<usize> = Vec::new();
        for (t, assign) in self.assignments.iter().enumerate() {
            if mask.get(t).copied().unwrap_or(0.0) == 0.0 {
                continue;
            }
            for &(e, _) in assign {
                if !set.contains(&e) {
                    set.push(e);
                }
            }
        }
        set.sort_unstable();
        set
    }

    /// expert -> masked token positions it must compute (one rank level).
    pub fn tokens_per_expert(&self, mask: &[f32]) -> BTreeMap<usize, Vec<(usize, f32)>> {
        let mut map: BTreeMap<usize, Vec<(usize, f32)>> = BTreeMap::new();
        for (t, assign) in self.assignments.iter().enumerate() {
            if mask.get(t).copied().unwrap_or(0.0) == 0.0 {
                continue;
            }
            for &(e, a) in assign {
                map.entry(e).or_default().push((t, a));
            }
        }
        map
    }
}

/// Who supplies expert weights to an invocation — the axis on which
/// SiDA and the baselines differ.
pub enum ExpertProvider<'a> {
    /// Everything staged on device up front (Standard / DeepSpeed-like /
    /// Tutel-like baselines; memory = full MoE bytes).
    AllResident(&'a HashMap<ExpertKey, [DeviceBuffer; 4]>),
    /// The SiDA cache: budget + eviction + modeled transfer cost.
    /// `blocking` marks fetches that stall the critical path.
    Cached { cache: &'a mut ExpertCache, blocking: bool },
    /// Same cache shared with a concurrent prefetcher (the two-thread
    /// SiDA pipeline).
    Shared { cache: &'a std::sync::Mutex<ExpertCache>, blocking: bool },
    /// Feed host literals every call (naive full offload; no device
    /// residency at all).
    HostLiterals,
}

/// Per-call switches for `ModelRunner::forward`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ForwardOptions {
    /// invoke every expert whether or not tokens were routed to it —
    /// the paper's "default implementation" (§2.3) used by Standard
    pub invoke_all: bool,
    /// pad every expert invocation to the full-L bucket (fixed capacity
    /// dispatch, DeepSpeed-style) instead of the adaptive smallest bucket
    pub fixed_bucket: bool,
    pub want_lm: bool,
    pub want_cls: bool,
}

/// One request in a cross-request batch handed to
/// [`ModelRunner::forward_batch`].
pub struct BatchItem<'a> {
    /// padded token ids, length == the runner's `seq_len`
    pub ids: &'a [i32],
    /// SiDA hash routing for this request as `(table, k_used)`; `None`
    /// runs the true router per MoE layer instead
    pub hash: Option<(&'a HashTable, usize)>,
}

/// One gathered token row inside an expert invocation: which request
/// of the batch it belongs to, its token position there, and the
/// combine weight applied at scatter time.
struct GatheredRow {
    item: usize,
    token: usize,
    alpha: f32,
}

/// Output of one forward pass.
pub struct ForwardOutput {
    /// final hidden states `[1, L, D]` (host values)
    pub hidden: Vec<f32>,
    pub lm_logits: Option<Vec<f32>>,
    pub cls_logits: Option<Vec<f32>>,
    /// per-MoE-layer routing actually used
    pub routing: Vec<RoutingDecision>,
    pub times: PhaseTimes,
}

/// Output of [`ModelRunner::forward_batch`].
pub struct BatchForwardOutput {
    /// per-request outputs, aligned with the input batch; their `times`
    /// are zeroed (see [`ModelRunner::forward_batch`])
    pub outputs: Vec<ForwardOutput>,
    /// batch-aggregate phase breakdown: expert invocations and H2D
    /// transfers are counted once per activated expert per batch
    pub times: PhaseTimes,
}

/// Stack per-request `[1, ...tail]` f32 literals into one `[B, ...tail]`.
fn stack_f32(parts: &[Literal]) -> Result<Literal> {
    let tail = &parts[0].shape()[1..];
    let per: usize = tail.iter().product();
    let mut data = Vec::with_capacity(parts.len() * per);
    for p in parts {
        data.extend_from_slice(p.f32s()?);
    }
    let mut shape = vec![parts.len()];
    shape.extend_from_slice(tail);
    Literal::from_f32s(&shape, data)
}

/// Split one `[B, ...tail]` f32 literal back into `B` `[1, ...tail]`
/// literals (exact value-preserving copies).
fn split_f32(batch: &Literal) -> Result<Vec<Literal>> {
    let b = batch.shape()[0];
    let tail = &batch.shape()[1..];
    let per: usize = tail.iter().product();
    let data = batch.f32s()?;
    let mut shape = vec![1usize];
    shape.extend_from_slice(tail);
    (0..b)
        .map(|i| Literal::from_f32s(&shape, data[i * per..(i + 1) * per].to_vec()))
        .collect()
}

/// Drives one model config at one profile seq-len.
pub struct ModelRunner {
    pub bundle: Arc<ModelBundle>,
    pub profile: String,
    pub seq_len: usize,
    exe_embed: Arc<Executable>,
    exe_attn: Arc<Executable>,
    exe_dense_ffn: Arc<Executable>,
    exe_moe_ln: Arc<Executable>,
    exe_router: Arc<Executable>,
    exe_combine: Arc<Executable>,
    exe_lm_head: Arc<Executable>,
    exe_cls_head: Arc<Executable>,
    exe_lm_nll: Arc<Executable>,
    exe_expert: BTreeMap<usize, Arc<Executable>>,
    /// cached host literals for all non-expert weights, keyed by name
    lits: HashMap<String, Literal>,
    /// positional table sliced to seq_len
    pos_lit: Literal,
}

impl ModelRunner {
    pub fn new(bundle: Arc<ModelBundle>, profile: &str) -> Result<Self> {
        let topo = &bundle.topology;
        let seq_len = topo.seq_len(profile)?;
        let eng = &bundle.engine;
        let l = seq_len;
        let exe_embed = eng.load(&format!("embed_L{l}"))?;
        let exe_attn = eng.load(&format!("attn_L{l}"))?;
        let exe_dense_ffn = eng.load(&format!("dense_ffn_L{l}"))?;
        let exe_moe_ln = eng.load(&format!("moe_ln_L{l}"))?;
        let exe_router = eng.load(&format!("router_L{l}"))?;
        let exe_combine = eng.load(&format!("moe_combine_L{l}"))?;
        let exe_lm_head = eng.load(&format!("lm_head_L{l}"))?;
        let exe_cls_head = eng.load(&format!("cls_head_L{l}"))?;
        let exe_lm_nll = eng.load(&format!("lm_nll_L{l}"))?;
        let mut exe_expert = BTreeMap::new();
        for &b in &topo.buckets {
            exe_expert.insert(b, eng.load(&format!("expert_T{b}"))?);
        }

        // cache host literals for every non-expert tensor we feed
        let mut lits = HashMap::new();
        let mut names: Vec<String> = vec![
            "embed.tok".into(),
            "final_ln_g".into(),
            "final_ln_b".into(),
            "lm_head.w".into(),
            "lm_head.b".into(),
            "cls_head.w".into(),
            "cls_head.b".into(),
        ];
        for b in 0..topo.n_blocks {
            for part in [
                "ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo", "ln2_g",
                "ln2_b",
            ] {
                names.push(format!("blocks.{b}.{part}"));
            }
            if topo.moe_layer_index(b).is_some() {
                names.push(format!("blocks.{b}.wr"));
            } else {
                for part in ["w1", "b1", "w2", "b2"] {
                    names.push(format!("blocks.{b}.{part}"));
                }
            }
        }
        for name in names {
            lits.insert(name.clone(), bundle.weights.literal(&name)?);
        }

        // positional slice [L, D]
        let pos_full = bundle.weights.f32_slice("embed.pos")?;
        let d = topo.d_model;
        let pos_lit = literal_from_f32s(&[l, d], &pos_full[..l * d])?;

        Ok(ModelRunner {
            bundle,
            profile: profile.to_string(),
            seq_len,
            exe_embed,
            exe_attn,
            exe_dense_ffn,
            exe_moe_ln,
            exe_router,
            exe_combine,
            exe_lm_head,
            exe_cls_head,
            exe_lm_nll,
            exe_expert,
            lits,
            pos_lit,
        })
    }

    fn lit(&self, name: &str) -> Result<&Literal> {
        self.lits
            .get(name)
            .with_context(|| format!("literal '{name}' not cached"))
    }

    /// Attention mask for padded ids — delegates to the canonical
    /// [`crate::workload::pad_mask`].
    pub fn mask_of(ids: &[i32]) -> Vec<f32> {
        crate::workload::pad_mask(ids)
    }

    /// Embed a sentence: ids (padded to seq_len) -> [1, L, D] literal.
    pub fn embed(&self, ids: &[i32]) -> Result<Literal> {
        debug_assert_eq!(ids.len(), self.seq_len);
        let ids_lit = literal_i32(&[1, self.seq_len], ids)?;
        let out = self
            .exe_embed
            .run(&[&ids_lit, self.lit("embed.tok")?, &self.pos_lit])?;
        Ok(out.into_iter().next().unwrap())
    }

    fn run_attn(&self, x: &Literal, mask: &Literal, block: usize) -> Result<Literal> {
        let b = block;
        let args: Vec<&Literal> = vec![
            x,
            mask,
            self.lit(&format!("blocks.{b}.ln1_g"))?,
            self.lit(&format!("blocks.{b}.ln1_b"))?,
            self.lit(&format!("blocks.{b}.wq"))?,
            self.lit(&format!("blocks.{b}.bq"))?,
            self.lit(&format!("blocks.{b}.wk"))?,
            self.lit(&format!("blocks.{b}.bk"))?,
            self.lit(&format!("blocks.{b}.wv"))?,
            self.lit(&format!("blocks.{b}.bv"))?,
            self.lit(&format!("blocks.{b}.wo"))?,
            self.lit(&format!("blocks.{b}.bo"))?,
        ];
        Ok(self.exe_attn.run(&args)?.into_iter().next().unwrap())
    }

    fn run_dense_ffn(&self, x: &Literal, block: usize) -> Result<Literal> {
        let b = block;
        let args: Vec<&Literal> = vec![
            x,
            self.lit(&format!("blocks.{b}.ln2_g"))?,
            self.lit(&format!("blocks.{b}.ln2_b"))?,
            self.lit(&format!("blocks.{b}.w1"))?,
            self.lit(&format!("blocks.{b}.b1"))?,
            self.lit(&format!("blocks.{b}.w2"))?,
            self.lit(&format!("blocks.{b}.b2"))?,
        ];
        Ok(self.exe_dense_ffn.run(&args)?.into_iter().next().unwrap())
    }

    fn run_moe_ln(&self, x: &Literal, block: usize) -> Result<Literal> {
        let b = block;
        let args: Vec<&Literal> = vec![
            x,
            self.lit(&format!("blocks.{b}.ln2_g"))?,
            self.lit(&format!("blocks.{b}.ln2_b"))?,
        ];
        Ok(self.exe_moe_ln.run(&args)?.into_iter().next().unwrap())
    }

    /// Run the true router on LN'd hidden states -> per-token top-1.
    pub fn run_router(&self, xln: &Literal, block: usize) -> Result<RoutingDecision> {
        let args: Vec<&Literal> =
            vec![xln, self.lit(&format!("blocks.{block}.wr"))?];
        let out = self.exe_router.run(&args)?;
        // outputs: logits [1,L,E], idx i32 [1,L], alpha [1,L]
        let idx = to_i32_vec(&out[1])?;
        let alpha = to_f32_vec(&out[2])?;
        let top1: Vec<usize> = idx.iter().map(|&i| i as usize).collect();
        let assignments = top1
            .iter()
            .zip(alpha.iter())
            .map(|(&e, &a)| vec![(e, a)])
            .collect();
        Ok(RoutingDecision { top1, assignments })
    }

    /// Routing decision from a SiDA hash table for one MoE layer.
    /// `k_used` experts per token, alphas renormalized over the k used
    /// (paper §4: top-1 for SST2, top-3 for MRPC/MultiRC).
    pub fn routing_from_hash(
        &self,
        table: &HashTable,
        moe_layer: usize,
        k_used: usize,
    ) -> RoutingDecision {
        let l = self.seq_len;
        let mut top1 = Vec::with_capacity(l);
        let mut assignments = Vec::with_capacity(l);
        for t in 0..l {
            let mut assign: Vec<(usize, f32)> = (0..k_used.min(table.k))
                .map(|r| {
                    (
                        table.expert_at(t, moe_layer, r),
                        table.alpha_at(t, moe_layer, r),
                    )
                })
                .collect();
            let norm: f32 = assign.iter().map(|(_, a)| *a).sum::<f32>().max(1e-9);
            for pair in assign.iter_mut() {
                pair.1 /= norm;
            }
            // rescale to the hash's top-1 confidence so magnitude tracks
            // the router's alpha (the student softmax approximates it)
            let lead = table.alpha_at(t, moe_layer, 0);
            for pair in assign.iter_mut() {
                pair.1 *= lead;
            }
            top1.push(assign[0].0);
            assignments.push(assign);
        }
        RoutingDecision { top1, assignments }
    }

    /// Invoke one expert on a packed token bucket gathered from one or
    /// more requests.  `xlns[i]` / `y_accs[i]` are request `i`'s LN'd
    /// hidden states and output accumulator.  Each packed row is
    /// computed independently by the expert FFN, so a (request, token)
    /// row's result is bit-identical no matter which other rows share
    /// the invocation — the property that lets the cross-request
    /// batched path reproduce sequential batch-1 serving exactly.
    #[allow(clippy::too_many_arguments)]
    fn invoke_expert_gathered(
        &self,
        block: usize,
        expert: usize,
        xlns: &[Vec<f32>],
        rows: &[GatheredRow],
        y_accs: &mut [Vec<f32>],
        provider: &mut ExpertProvider<'_>,
        fixed_bucket: bool,
        times: &mut PhaseTimes,
    ) -> Result<()> {
        let d = self.bundle.topology.d_model;
        let count = rows.len().max(1);
        let bucket = if fixed_bucket {
            self.bundle.topology.bucket_for(self.seq_len)
        } else {
            self.bundle.topology.bucket_for(count)
        };
        if count > bucket {
            // split across multiple calls (count > largest bucket)
            let (head, tail) = rows.split_at(bucket);
            self.invoke_expert_gathered(
                block, expert, xlns, head, y_accs, provider, fixed_bucket, times,
            )?;
            return self.invoke_expert_gathered(
                block, expert, xlns, tail, y_accs, provider, fixed_bucket, times,
            );
        }
        // pack tokens
        let mut packed = vec![0f32; bucket * d];
        for (r, row) in rows.iter().enumerate() {
            let src = &xlns[row.item][row.token * d..(row.token + 1) * d];
            packed[r * d..(r + 1) * d].copy_from_slice(src);
        }
        let exe = self
            .exe_expert
            .get(&bucket)
            .with_context(|| format!("no expert artifact for bucket {bucket}"))?;

        let key = ExpertKey::new(block, expert);
        // Residency first (transfer time accounted separately from
        // dispatch/compute time so Fig 3's breakdown stays honest).
        let fetch = || -> Result<[DeviceBuffer; 4]> {
            crate::runtime::stage_expert_parts(
                &self.bundle.engine,
                &self.bundle.weights,
                block,
                expert,
            )
        };
        let resident_for_cache = match provider {
            ExpertProvider::Cached { cache, blocking } => {
                let real_bytes = self.bundle.weights.expert_bytes(block, expert)?;
                let (resident, _hit, secs) = cache.ensure(key, real_bytes, *blocking, fetch)?;
                times.transfer_secs += secs;
                cache.pin(key);
                Some(resident)
            }
            ExpertProvider::Shared { cache, blocking } => {
                let real_bytes = self.bundle.weights.expert_bytes(block, expert)?;
                let mut guard = cache.lock().unwrap();
                let (resident, _hit, secs) = guard.ensure(key, real_bytes, *blocking, fetch)?;
                times.transfer_secs += secs;
                guard.pin(key);
                Some(resident)
            }
            _ => None,
        };

        let t0 = Instant::now();
        let out = match provider {
            ExpertProvider::AllResident(map) => {
                let parts = map
                    .get(&key)
                    .with_context(|| format!("expert {key:?} not staged"))?;
                let x_buf = self.bundle.engine.stage_f32(&[bucket, d], &packed)?;
                let bufs: Vec<&DeviceBuffer> =
                    vec![&x_buf, &parts[0], &parts[1], &parts[2], &parts[3]];
                exe.run_buffers(&bufs)?
            }
            ExpertProvider::Cached { cache, .. } => {
                let resident = resident_for_cache.as_ref().unwrap();
                let x_buf = self.bundle.engine.stage_f32(&[bucket, d], &packed)?;
                let bufs: Vec<&DeviceBuffer> = vec![
                    &x_buf,
                    &resident.parts[0],
                    &resident.parts[1],
                    &resident.parts[2],
                    &resident.parts[3],
                ];
                let out = exe.run_buffers(&bufs)?;
                cache.unpin(&key);
                out
            }
            ExpertProvider::Shared { cache, .. } => {
                let resident = resident_for_cache.as_ref().unwrap();
                let x_buf = self.bundle.engine.stage_f32(&[bucket, d], &packed)?;
                let bufs: Vec<&DeviceBuffer> = vec![
                    &x_buf,
                    &resident.parts[0],
                    &resident.parts[1],
                    &resident.parts[2],
                    &resident.parts[3],
                ];
                let out = exe.run_buffers(&bufs)?;
                cache.lock().unwrap().unpin(&key);
                out
            }
            ExpertProvider::HostLiterals => {
                let names = crate::runtime::WeightStore::expert_part_names(block, expert);
                let x_lit = literal_from_f32s(&[bucket, d], &packed)?;
                let owned = [
                    x_lit,
                    self.bundle.weights.literal(&names[0])?,
                    self.bundle.weights.literal(&names[1])?,
                    self.bundle.weights.literal(&names[2])?,
                    self.bundle.weights.literal(&names[3])?,
                ];
                let args: Vec<&Literal> = owned.iter().collect();
                exe.run(&args)?
            }
        };
        times.expert_secs += t0.elapsed().as_secs_f64();
        times.expert_invocations += 1;

        // scatter weighted rows back
        let y = to_f32_vec(&out[0])?;
        for (r, row) in rows.iter().enumerate() {
            let dst = &mut y_accs[row.item][row.token * d..(row.token + 1) * d];
            let src = &y[r * d..(r + 1) * d];
            for (o, v) in dst.iter_mut().zip(src.iter()) {
                *o += row.alpha * v;
            }
        }
        Ok(())
    }

    /// Run one MoE layer given a routing decision.  The decision's
    /// alphas are applied host-side during scatter; the combine artifact
    /// adds the residual with alpha=1 on real tokens.
    #[allow(clippy::too_many_arguments)]
    pub fn run_moe_layer(
        &self,
        x: &Literal,
        mask_host: &[f32],
        mask_lit: &Literal,
        block: usize,
        routing: &RoutingDecision,
        provider: &mut ExpertProvider<'_>,
        opts: ForwardOptions,
        times: &mut PhaseTimes,
    ) -> Result<Literal> {
        let topo = &self.bundle.topology;
        let d = topo.d_model;
        let l = self.seq_len;
        let xln = self.run_moe_ln(x, block)?;
        let xln_host = to_f32_vec(&xln)?;
        let mut y_acc = vec![0f32; l * d];
        let per_expert = routing.tokens_per_expert(mask_host);

        let gather = |assignments: &[(usize, f32)]| -> Vec<GatheredRow> {
            assignments
                .iter()
                .map(|&(t, a)| GatheredRow { item: 0, token: t, alpha: a })
                .collect()
        };
        if opts.invoke_all {
            // the paper's default implementation: every expert is invoked
            // whether or not tokens were assigned to it (§2.3)
            for expert in 0..topo.num_experts {
                let assignments = per_expert
                    .get(&expert)
                    .cloned()
                    .unwrap_or_else(|| vec![(0usize, 0.0f32)]);
                self.invoke_expert_gathered(
                    block,
                    expert,
                    std::slice::from_ref(&xln_host),
                    &gather(&assignments),
                    std::slice::from_mut(&mut y_acc),
                    provider,
                    opts.fixed_bucket,
                    times,
                )?;
            }
        } else {
            for (expert, assignments) in per_expert.iter() {
                self.invoke_expert_gathered(
                    block,
                    *expert,
                    std::slice::from_ref(&xln_host),
                    &gather(assignments),
                    std::slice::from_mut(&mut y_acc),
                    provider,
                    opts.fixed_bucket,
                    times,
                )?;
            }
        }

        let y_lit = literal_from_f32s(&[1, l, d], &y_acc)?;
        let ones = literal_from_f32s(&[1, l], &vec![1.0f32; l])?;
        let out = self
            .exe_combine
            .run(&[x, &y_lit, &ones, mask_lit])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Full forward pass.  `routing_for` supplies the per-MoE-layer
    /// decision: SiDA reads the hash table; baselines run the router
    /// (passing `None` here runs the router on the fly).
    pub fn forward(
        &self,
        ids: &[i32],
        hash_routing: Option<(&HashTable, usize)>,
        provider: &mut ExpertProvider<'_>,
        opts: ForwardOptions,
    ) -> Result<ForwardOutput> {
        let topo = self.bundle.topology.clone();
        if ids.len() != self.seq_len {
            bail!("ids len {} != seq_len {}", ids.len(), self.seq_len);
        }
        let mut times = PhaseTimes::default();
        let mask_host = Self::mask_of(ids);
        let mask_lit = literal_from_f32s(&[1, self.seq_len], &mask_host)?;

        let t0 = Instant::now();
        let mut x = self.embed(ids)?;
        times.dense_secs += t0.elapsed().as_secs_f64();

        let mut routing_used = Vec::new();
        for block in 0..topo.n_blocks {
            let t_attn = Instant::now();
            x = self.run_attn(&x, &mask_lit, block)?;
            times.dense_secs += t_attn.elapsed().as_secs_f64();

            match topo.moe_layer_index(block) {
                None => {
                    let t_ffn = Instant::now();
                    x = self.run_dense_ffn(&x, block)?;
                    times.dense_secs += t_ffn.elapsed().as_secs_f64();
                }
                Some(moe_layer) => {
                    // expert selection
                    let t_sel = Instant::now();
                    let routing = match hash_routing {
                        Some((table, k_used)) => {
                            self.routing_from_hash(table, moe_layer, k_used)
                        }
                        None => {
                            let xln = self.run_moe_ln(&x, block)?;
                            self.run_router(&xln, block)?
                        }
                    };
                    times.selection_secs += t_sel.elapsed().as_secs_f64();

                    x = self.run_moe_layer(
                        &x, &mask_host, &mask_lit, block, &routing, provider, opts, &mut times,
                    )?;
                    routing_used.push(routing);
                }
            }
        }

        let mut lm_logits = None;
        let mut cls_logits = None;
        let t_head = Instant::now();
        if opts.want_lm {
            let out = self.exe_lm_head.run(&[
                &x,
                self.lit("final_ln_g")?,
                self.lit("final_ln_b")?,
                self.lit("lm_head.w")?,
                self.lit("lm_head.b")?,
            ])?;
            lm_logits = Some(to_f32_vec(&out[0])?);
        }
        if opts.want_cls {
            let out = self.exe_cls_head.run(&[
                &x,
                &mask_lit,
                self.lit("final_ln_g")?,
                self.lit("final_ln_b")?,
                self.lit("cls_head.w")?,
                self.lit("cls_head.b")?,
            ])?;
            cls_logits = Some(to_f32_vec(&out[0])?);
        }
        times.dense_secs += t_head.elapsed().as_secs_f64();

        let hidden = to_f32_vec(&x)?;
        Ok(ForwardOutput {
            hidden,
            lm_logits,
            cls_logits,
            routing: routing_used,
            times,
        })
    }

    /// Cross-request batched forward pass.
    ///
    /// The dense per-sequence stages (embed, attention, dense FFN,
    /// heads) run for every request — as one stacked `[B, L, ...]`
    /// dispatch per stage when the backend reports
    /// [`batched_entries`](crate::runtime::Backend::batched_entries),
    /// else as a per-request loop — while every MoE layer **gathers the
    /// tokens routed to the same expert across the whole batch and
    /// issues one expert invocation per activated expert**, not one per
    /// request.  Each expert's residency is ensured (and its H2D
    /// transfer charged) once per batch, which is where the paper's
    /// batch-level amortization of expert traffic comes from.
    ///
    /// Outputs are bit-identical to running [`ModelRunner::forward`] on
    /// each request sequentially: the expert FFN computes packed rows
    /// independently, and per-token accumulation order is preserved
    /// (experts ascending, tokens in sequence order).  Per-request
    /// `times` in the returned outputs are zeroed — under shared
    /// dispatch per-request phase attribution is not meaningful; use
    /// the batch-level [`BatchForwardOutput::times`].
    pub fn forward_batch(
        &self,
        items: &[BatchItem<'_>],
        provider: &mut ExpertProvider<'_>,
        opts: ForwardOptions,
    ) -> Result<BatchForwardOutput> {
        let topo = self.bundle.topology.clone();
        let n = items.len();
        anyhow::ensure!(n > 0, "forward_batch: empty batch");
        for it in items {
            if it.ids.len() != self.seq_len {
                bail!("ids len {} != seq_len {}", it.ids.len(), self.seq_len);
            }
        }
        let l = self.seq_len;
        let batched = n > 1 && self.bundle.engine.batched_entries();
        let mut times = PhaseTimes::default();

        let masks: Vec<Vec<f32>> = items.iter().map(|it| Self::mask_of(it.ids)).collect();
        let mask_lits: Vec<Literal> = masks
            .iter()
            .map(|m| literal_from_f32s(&[1, l], m))
            .collect::<Result<_>>()?;
        let mask_stack = if batched {
            let mut flat = Vec::with_capacity(n * l);
            for m in &masks {
                flat.extend_from_slice(m);
            }
            Some(literal_from_f32s(&[n, l], &flat)?)
        } else {
            None
        };

        let t0 = Instant::now();
        let mut xs = self.embed_many(items, batched)?;
        times.dense_secs += t0.elapsed().as_secs_f64();

        let mut routing_used: Vec<Vec<RoutingDecision>> = (0..n).map(|_| Vec::new()).collect();
        for block in 0..topo.n_blocks {
            let t_attn = Instant::now();
            xs = self.attn_many(&xs, &mask_lits, mask_stack.as_ref(), block)?;
            times.dense_secs += t_attn.elapsed().as_secs_f64();

            match topo.moe_layer_index(block) {
                None => {
                    let t_ffn = Instant::now();
                    xs = self.dense_ffn_many(&xs, batched, block)?;
                    times.dense_secs += t_ffn.elapsed().as_secs_f64();
                }
                Some(moe_layer) => {
                    // LN'd hidden states serve both the router (when no
                    // hash table routes) and the expert gather — compute
                    // them once per request per layer
                    let xln_hosts = self.moe_ln_hosts(&xs, batched, block)?;
                    let d = topo.d_model;

                    // per-request expert selection (hash table or router)
                    let t_sel = Instant::now();
                    let mut routings = Vec::with_capacity(n);
                    for (i, it) in items.iter().enumerate() {
                        let routing = match it.hash {
                            Some((table, k_used)) => {
                                self.routing_from_hash(table, moe_layer, k_used)
                            }
                            None => {
                                // rebuilt from the host copy: value-identical
                                // to a fresh moe_ln dispatch
                                let xln = literal_from_f32s(&[1, l, d], &xln_hosts[i])?;
                                self.run_router(&xln, block)?
                            }
                        };
                        routings.push(routing);
                    }
                    times.selection_secs += t_sel.elapsed().as_secs_f64();

                    let mut y_accs: Vec<Vec<f32>> =
                        (0..n).map(|_| vec![0f32; l * d]).collect();
                    let mut union: BTreeMap<usize, Vec<GatheredRow>> = BTreeMap::new();
                    for (i, routing) in routings.iter().enumerate() {
                        for (expert, assigns) in routing.tokens_per_expert(&masks[i]) {
                            union.entry(expert).or_default().extend(
                                assigns
                                    .iter()
                                    .map(|&(t, a)| GatheredRow { item: i, token: t, alpha: a }),
                            );
                        }
                    }
                    if opts.invoke_all {
                        for expert in 0..topo.num_experts {
                            let rows = union.remove(&expert).unwrap_or_else(|| {
                                vec![GatheredRow { item: 0, token: 0, alpha: 0.0 }]
                            });
                            self.invoke_expert_gathered(
                                block, expert, &xln_hosts, &rows, &mut y_accs, provider,
                                opts.fixed_bucket, &mut times,
                            )?;
                        }
                    } else {
                        for (expert, rows) in union.iter() {
                            self.invoke_expert_gathered(
                                block, *expert, &xln_hosts, rows, &mut y_accs, provider,
                                opts.fixed_bucket, &mut times,
                            )?;
                        }
                    }
                    xs = self.combine_many(&xs, &y_accs, &mask_lits, mask_stack.as_ref())?;
                    for (i, routing) in routings.into_iter().enumerate() {
                        routing_used[i].push(routing);
                    }
                }
            }
        }

        // heads per request
        let t_head = Instant::now();
        let mut outputs = Vec::with_capacity(n);
        for i in 0..n {
            let x = &xs[i];
            let mut lm_logits = None;
            let mut cls_logits = None;
            if opts.want_lm {
                let out = self.exe_lm_head.run(&[
                    x,
                    self.lit("final_ln_g")?,
                    self.lit("final_ln_b")?,
                    self.lit("lm_head.w")?,
                    self.lit("lm_head.b")?,
                ])?;
                lm_logits = Some(to_f32_vec(&out[0])?);
            }
            if opts.want_cls {
                let out = self.exe_cls_head.run(&[
                    x,
                    &mask_lits[i],
                    self.lit("final_ln_g")?,
                    self.lit("final_ln_b")?,
                    self.lit("cls_head.w")?,
                    self.lit("cls_head.b")?,
                ])?;
                cls_logits = Some(to_f32_vec(&out[0])?);
            }
            outputs.push(ForwardOutput {
                hidden: to_f32_vec(x)?,
                lm_logits,
                cls_logits,
                routing: std::mem::take(&mut routing_used[i]),
                times: PhaseTimes::default(),
            });
        }
        times.dense_secs += t_head.elapsed().as_secs_f64();
        Ok(BatchForwardOutput { outputs, times })
    }

    /// Embed every request of a batch (one stacked dispatch when the
    /// backend supports batched entries, else per request).
    fn embed_many(&self, items: &[BatchItem<'_>], batched: bool) -> Result<Vec<Literal>> {
        if batched {
            let l = self.seq_len;
            let mut ids = Vec::with_capacity(items.len() * l);
            for it in items {
                ids.extend_from_slice(it.ids);
            }
            let ids_lit = literal_i32(&[items.len(), l], &ids)?;
            let out = self
                .exe_embed
                .run(&[&ids_lit, self.lit("embed.tok")?, &self.pos_lit])?;
            split_f32(&out[0])
        } else {
            items.iter().map(|it| self.embed(it.ids)).collect()
        }
    }

    fn attn_many(
        &self,
        xs: &[Literal],
        mask_lits: &[Literal],
        mask_stack: Option<&Literal>,
        block: usize,
    ) -> Result<Vec<Literal>> {
        match mask_stack {
            Some(mask) => {
                let stacked = stack_f32(xs)?;
                split_f32(&self.run_attn(&stacked, mask, block)?)
            }
            None => xs
                .iter()
                .zip(mask_lits.iter())
                .map(|(x, m)| self.run_attn(x, m, block))
                .collect(),
        }
    }

    fn dense_ffn_many(&self, xs: &[Literal], batched: bool, block: usize) -> Result<Vec<Literal>> {
        if batched {
            let stacked = stack_f32(xs)?;
            split_f32(&self.run_dense_ffn(&stacked, block)?)
        } else {
            xs.iter().map(|x| self.run_dense_ffn(x, block)).collect()
        }
    }

    /// LN'd hidden states of every request as host buffers — the gather
    /// source for the batch-wide expert dispatch.
    fn moe_ln_hosts(&self, xs: &[Literal], batched: bool, block: usize) -> Result<Vec<Vec<f32>>> {
        if batched {
            let stacked = stack_f32(xs)?;
            let host = to_f32_vec(&self.run_moe_ln(&stacked, block)?)?;
            let per = host.len() / xs.len();
            Ok(host.chunks(per).map(|c| c.to_vec()).collect())
        } else {
            xs.iter()
                .map(|x| to_f32_vec(&self.run_moe_ln(x, block)?))
                .collect()
        }
    }

    fn combine_many(
        &self,
        xs: &[Literal],
        y_accs: &[Vec<f32>],
        mask_lits: &[Literal],
        mask_stack: Option<&Literal>,
    ) -> Result<Vec<Literal>> {
        let l = self.seq_len;
        let d = self.bundle.topology.d_model;
        match mask_stack {
            Some(mask) => {
                let n = xs.len();
                let stacked = stack_f32(xs)?;
                let mut y = Vec::with_capacity(n * l * d);
                for acc in y_accs {
                    y.extend_from_slice(acc);
                }
                let y_lit = literal_from_f32s(&[n, l, d], &y)?;
                let ones = literal_from_f32s(&[n, l], &vec![1.0f32; n * l])?;
                let out = self.exe_combine.run(&[&stacked, &y_lit, &ones, mask])?;
                split_f32(&out[0])
            }
            None => {
                let ones = literal_from_f32s(&[1, l], &vec![1.0f32; l])?;
                xs.iter()
                    .zip(y_accs.iter())
                    .zip(mask_lits.iter())
                    .map(|((x, acc), m)| {
                        let y_lit = literal_from_f32s(&[1, l, d], acc)?;
                        let out = self.exe_combine.run(&[x, &y_lit, &ones, m])?;
                        Ok(out.into_iter().next().unwrap())
                    })
                    .collect()
            }
        }
    }

    /// Per-sentence LM NLL + token count via the lm_nll artifact.
    pub fn lm_nll(&self, lm_logits: &[f32], ids: &[i32]) -> Result<(f64, f64)> {
        let l = self.seq_len;
        let v = self.bundle.topology.vocab;
        let mask = Self::mask_of(ids);
        let logits_lit = literal_from_f32s(&[1, l, v], lm_logits)?;
        let ids_lit = literal_i32(&[1, l], ids)?;
        let mask_lit = literal_from_f32s(&[1, l], &mask)?;
        let out = self.exe_lm_nll.run(&[&logits_lit, &ids_lit, &mask_lit])?;
        let nll = to_f32_vec(&out[0])?[0] as f64;
        let cnt = to_f32_vec(&out[1])?[0] as f64;
        Ok((nll, cnt))
    }

    /// Stage every expert of every MoE layer on device (baseline setup).
    pub fn stage_all_experts(&self) -> Result<HashMap<ExpertKey, [DeviceBuffer; 4]>> {
        let topo = &self.bundle.topology;
        let mut map = HashMap::new();
        for &block in &topo.moe_blocks {
            for expert in 0..topo.num_experts {
                map.insert(
                    ExpertKey::new(block, expert),
                    crate::runtime::stage_expert_parts(
                        &self.bundle.engine,
                        &self.bundle.weights,
                        block,
                        expert,
                    )?,
                );
            }
        }
        Ok(map)
    }
}
