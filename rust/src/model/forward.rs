//! Forward-pass orchestration: the Rust twin of `python/compile/model.py`.
//!
//! The paper evaluates at batch size 1 (§2: "all experiments are
//! conducted with a batch size of 1 to isolate the influence of batch
//! size") — [`ModelRunner::forward`], where a sequence of L tokens
//! flows through artifacts specialized to `[1, L]`.
//! [`ModelRunner::forward_batch`] extends the same arithmetic to
//! cross-request batches: dense stages per request (or stacked, when
//! the backend supports batched entries), expert dispatch shared across
//! the batch, outputs bit-identical to sequential forwards.
//!
//! ## Parallel expert execution
//!
//! The gathered per-expert invocations of each MoE layer run
//! concurrently on the runner's [`WorkerPool`] (experts are
//! independent: each consumes its own token rows).  Determinism is
//! preserved by construction: workers only *compute* — each invocation
//! produces a private output buffer — and the weighted scatter back
//! into the accumulators happens on the calling thread afterwards, in
//! ascending expert order, exactly the order the sequential path uses.
//! Same accumulation order ⇒ bit-identical f32 outputs at every pool
//! width (asserted in `tests/integration.rs`).

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::cluster::ClusterRouter;
use crate::coordinator::hash_table::HashTable;
use crate::experts::{ExpertCache, ExpertKey, SharedExpertCache};
use crate::obs::trace::{self, ArgValue};
use crate::runtime::{
    literal_from_f32s, literal_i32, to_f32_vec, to_i32_vec, DeviceBuffer, Executable, Literal,
    ModelBundle,
};
use crate::util::pool::WorkerPool;
use crate::util::sync::LayerGate;

/// Wall-time breakdown of one forward pass (Fig 3's phases, refined
/// with the host-side gather/scatter stages and the pooled-execution
/// wall clock).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimes {
    /// embed + attention + dense FFN + heads — the paper's "ideal
    /// inference time"
    pub dense_secs: f64,
    /// router execution (baselines) or hash-table wait (SiDA)
    pub selection_secs: f64,
    /// host-side gather: routing decisions -> per-expert token row sets
    pub gather_secs: f64,
    /// per-invocation dispatch compute, summed over invocations (the
    /// serial cost of the expert work, independent of pooling)
    pub expert_secs: f64,
    /// wall clock of the (possibly pooled) expert-execution section —
    /// with N workers this is what the critical path actually pays,
    /// `<= expert_secs` when the pool overlaps invocations
    pub expert_wall_secs: f64,
    /// weighted scatter of expert outputs back into the accumulators
    pub scatter_secs: f64,
    /// wall seconds the inference thread spent blocked at the layer
    /// gate waiting for the warmer — the *measured* cost of imperfect
    /// overlap (0 when warm-up fully hides behind compute), charged to
    /// the critical path
    pub stall_secs: f64,
    /// modeled H2D transfer time charged on the critical path (blocking
    /// fetches); overlapped prefetch transfers are accounted
    /// cache-side, not here
    pub transfer_secs: f64,
    /// number of expert invocations issued
    pub expert_invocations: u64,
}

impl PhaseTimes {
    /// Serial-cost total: every phase as if executed back to back
    /// (`expert_secs`, not the pooled wall).  The Fig 3 axis.
    pub fn total(&self) -> f64 {
        self.dense_secs
            + self.selection_secs
            + self.gather_secs
            + self.expert_secs
            + self.scatter_secs
            + self.transfer_secs
    }

    pub fn moe_overhead(&self) -> f64 {
        self.selection_secs
            + self.gather_secs
            + self.expert_secs
            + self.scatter_secs
            + self.transfer_secs
    }

    /// Critical-path seconds actually elapsed on the inference thread:
    /// dense + selection + gather + the pooled expert wall + scatter +
    /// layer-gate stalls.  Including `stall_secs` keeps the metric
    /// honest: if the warmer cannot keep ahead of compute, the wait
    /// shows up here instead of disappearing into "overlapped".
    /// Exposed (non-overlapped) modeled transfer is tracked cache-side
    /// and added by [`crate::metrics::ServeStats::modeled_request_secs`].
    pub fn critical_path_secs(&self) -> f64 {
        self.dense_secs
            + self.selection_secs
            + self.gather_secs
            + self.expert_wall_secs
            + self.scatter_secs
            + self.stall_secs
    }

    pub fn add(&mut self, other: &PhaseTimes) {
        self.dense_secs += other.dense_secs;
        self.selection_secs += other.selection_secs;
        self.gather_secs += other.gather_secs;
        self.expert_secs += other.expert_secs;
        self.expert_wall_secs += other.expert_wall_secs;
        self.scatter_secs += other.scatter_secs;
        self.stall_secs += other.stall_secs;
        self.transfer_secs += other.transfer_secs;
        self.expert_invocations += other.expert_invocations;
    }
}

/// Per-MoE-layer routing decision: for each token, the experts that
/// compute it and their (renormalized) combine weights.
#[derive(Debug, Clone)]
pub struct RoutingDecision {
    /// `[L]` primary expert per token (rank 0)
    pub top1: Vec<usize>,
    /// token -> [(expert, alpha)] for k_used experts
    pub assignments: Vec<Vec<(usize, f32)>>,
}

impl RoutingDecision {
    /// Unique experts used, ascending.
    pub fn active_experts(&self, mask: &[f32]) -> Vec<usize> {
        let mut set: Vec<usize> = Vec::new();
        for (t, assign) in self.assignments.iter().enumerate() {
            if mask.get(t).copied().unwrap_or(0.0) == 0.0 {
                continue;
            }
            for &(e, _) in assign {
                if !set.contains(&e) {
                    set.push(e);
                }
            }
        }
        set.sort_unstable();
        set
    }

    /// expert -> masked token positions it must compute (one rank level).
    pub fn tokens_per_expert(&self, mask: &[f32]) -> BTreeMap<usize, Vec<(usize, f32)>> {
        let mut map: BTreeMap<usize, Vec<(usize, f32)>> = BTreeMap::new();
        for (t, assign) in self.assignments.iter().enumerate() {
            if mask.get(t).copied().unwrap_or(0.0) == 0.0 {
                continue;
            }
            for &(e, a) in assign {
                map.entry(e).or_default().push((t, a));
            }
        }
        map
    }
}

/// Who supplies expert weights to an invocation — the axis on which
/// SiDA and the baselines differ.
pub enum ExpertProvider<'a> {
    /// Everything staged on device up front (Standard / DeepSpeed-like /
    /// Tutel-like baselines; memory = full MoE bytes).
    AllResident(&'a HashMap<ExpertKey, [DeviceBuffer; 4]>),
    /// The SiDA cache: budget + eviction + modeled transfer cost.
    /// `blocking` marks fetches that stall the critical path.
    Cached { cache: &'a mut ExpertCache, blocking: bool },
    /// The same cache shared with the concurrent prefetch/warmer stages
    /// and the worker pool (lookups under a read lock, mutation under a
    /// write lock — see [`SharedExpertCache`]).
    Shared { cache: &'a SharedExpertCache, blocking: bool },
    /// Multi-device expert parallelism: each MoE layer's gathered
    /// expert jobs are partitioned across the cluster's modeled devices
    /// (home/replica placement decides who computes what), one worker
    /// lane per device, residency resolved through each device's own
    /// shared cache — see [`crate::cluster`].  Outputs stay
    /// bit-identical to the single-device path: lanes only compute, and
    /// the caller scatters in ascending expert order as always.
    Cluster { router: &'a ClusterRouter, blocking: bool },
    /// Feed host literals every call (naive full offload; no device
    /// residency at all).
    HostLiterals,
}

/// Per-call switches for `ModelRunner::forward`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ForwardOptions {
    /// invoke every expert whether or not tokens were routed to it —
    /// the paper's "default implementation" (§2.3) used by Standard
    pub invoke_all: bool,
    /// pad every expert invocation to the full-L bucket (fixed capacity
    /// dispatch, DeepSpeed-style) instead of the adaptive smallest bucket
    pub fixed_bucket: bool,
    pub want_lm: bool,
    pub want_cls: bool,
}

/// Out-of-band hooks into a forward pass.  [`ForwardHooks::layer_gate`]
/// couples the pass to the depth-window warmer: before dispatching MoE
/// layer *j* the runner waits until the warmer has staged layer *j*'s
/// experts (and publishes its progress so the warmer can advance its
/// window to *j+1 .. j+depth*, each staged fetch scheduled
/// earliest-deadline-first into the shared bandwidth window — see
/// `experts::bandwidth`), which keeps every expert fetch on the
/// overlapped prefetch timeline.
#[derive(Clone, Copy, Default)]
pub struct ForwardHooks<'a> {
    pub layer_gate: Option<&'a LayerGate>,
    /// Request ids aligned with the batch items, used by the span
    /// tracer (`crate::obs::trace`) to emit flow steps that tie each
    /// device lane back to the requests it computed.  `None` (or a
    /// disabled tracer) emits no flow events.
    pub trace_ids: Option<&'a [u64]>,
}

/// One request in a cross-request batch handed to
/// [`ModelRunner::forward_batch`].
pub struct BatchItem<'a> {
    /// padded token ids, length == the runner's `seq_len`
    pub ids: &'a [i32],
    /// SiDA hash routing for this request as `(table, k_used)`; `None`
    /// runs the true router per MoE layer instead
    pub hash: Option<(&'a HashTable, usize)>,
}

/// One gathered token row inside an expert invocation: which request
/// of the batch it belongs to, its token position there, and the
/// combine weight applied at scatter time.
struct GatheredRow {
    item: usize,
    token: usize,
    alpha: f32,
}

/// One expert's work for an MoE layer: the token rows routed to it
/// (in deterministic gather order).
struct ExpertJob {
    expert: usize,
    rows: Vec<GatheredRow>,
}

/// A worker's view of the expert provider: the parallel-capable
/// variants only (the `Cached { &mut .. }` provider is inherently
/// single-owner and runs inline through [`CachedDispatch`]).
enum ParProvider<'a> {
    AllResident(&'a HashMap<ExpertKey, [DeviceBuffer; 4]>),
    Shared { cache: &'a SharedExpertCache, blocking: bool },
    HostLiterals,
}

/// Result of dispatching one packed chunk through a residency resolver.
struct ChunkOut {
    result: Vec<Literal>,
    transfer_secs: f64,
    dispatch_secs: f64,
}

/// The residency-resolver axis of an expert invocation: how one packed
/// chunk finds its staged weights.  The chunk/pack loop itself is shared
/// ([`ModelRunner::compute_expert_rows`]); only this resolution step
/// differs between provider variants, so the historical duplicated twin
/// of the loop for the single-owner `&mut ExpertCache` provider is gone.
trait ExpertDispatch {
    fn dispatch_chunk(
        &self,
        runner: &ModelRunner,
        key: ExpertKey,
        exe: &Executable,
        bucket: usize,
        packed: &[f32],
    ) -> Result<ChunkOut>;
}

impl ExpertDispatch for ParProvider<'_> {
    fn dispatch_chunk(
        &self,
        runner: &ModelRunner,
        key: ExpertKey,
        exe: &Executable,
        bucket: usize,
        packed: &[f32],
    ) -> Result<ChunkOut> {
        match self {
            ParProvider::AllResident(map) => {
                let parts = map
                    .get(&key)
                    .with_context(|| format!("expert {key:?} not staged"))?;
                let t0 = Instant::now();
                let result = runner.dispatch_chunk(exe, bucket, packed, parts)?;
                Ok(ChunkOut {
                    result,
                    transfer_secs: 0.0,
                    dispatch_secs: t0.elapsed().as_secs_f64(),
                })
            }
            ParProvider::Shared { cache, blocking } => {
                // unpin on every exit path — a panic that leaks a
                // pin would wedge concurrent AllPinned waiters
                struct Unpin<'a>(&'a SharedExpertCache, ExpertKey);
                impl Drop for Unpin<'_> {
                    fn drop(&mut self) {
                        self.0.unpin(&self.1);
                    }
                }
                let real_bytes = runner.bundle.weights.expert_bytes(key.block, key.expert)?;
                let (resident, _hit, secs) =
                    cache.ensure_pinned(key, real_bytes, *blocking, || {
                        crate::runtime::stage_expert_parts(
                            &runner.bundle.engine,
                            &runner.bundle.weights,
                            key.block,
                            key.expert,
                        )
                    })?;
                let _unpin = Unpin(*cache, key);
                let t0 = Instant::now();
                let result = runner.dispatch_chunk(exe, bucket, packed, &resident.parts)?;
                Ok(ChunkOut {
                    result,
                    transfer_secs: secs,
                    dispatch_secs: t0.elapsed().as_secs_f64(),
                })
            }
            ParProvider::HostLiterals => {
                let d = runner.bundle.topology.d_model;
                let names =
                    crate::runtime::WeightStore::expert_part_names(key.block, key.expert);
                let x_lit = literal_from_f32s(&[bucket, d], packed)?;
                let owned = [
                    x_lit,
                    runner.bundle.weights.literal(&names[0])?,
                    runner.bundle.weights.literal(&names[1])?,
                    runner.bundle.weights.literal(&names[2])?,
                    runner.bundle.weights.literal(&names[3])?,
                ];
                let args: Vec<&Literal> = owned.iter().collect();
                let t0 = Instant::now();
                let result = exe.run(&args)?;
                Ok(ChunkOut {
                    result,
                    transfer_secs: 0.0,
                    dispatch_secs: t0.elapsed().as_secs_f64(),
                })
            }
        }
    }
}

/// Residency resolver for the single-owner `Cached { &mut ExpertCache }`
/// provider.  Runs inline on the calling thread only (a `RefCell` is
/// not `Sync`, which is exactly the point: this variant never crosses
/// the pool), sharing the chunk loop with every parallel variant.
struct CachedDispatch<'a> {
    cache: RefCell<&'a mut ExpertCache>,
    blocking: bool,
}

impl ExpertDispatch for CachedDispatch<'_> {
    fn dispatch_chunk(
        &self,
        runner: &ModelRunner,
        key: ExpertKey,
        exe: &Executable,
        bucket: usize,
        packed: &[f32],
    ) -> Result<ChunkOut> {
        let mut cache = self.cache.borrow_mut();
        let real_bytes = runner.bundle.weights.expert_bytes(key.block, key.expert)?;
        let (resident, _hit, secs) = cache.ensure(key, real_bytes, self.blocking, || {
            crate::runtime::stage_expert_parts(
                &runner.bundle.engine,
                &runner.bundle.weights,
                key.block,
                key.expert,
            )
        })?;
        cache.pin(key);
        let t0 = Instant::now();
        let result = runner.dispatch_chunk(exe, bucket, packed, &resident.parts);
        let dispatch_secs = t0.elapsed().as_secs_f64();
        cache.unpin(&key);
        Ok(ChunkOut { result: result?, transfer_secs: secs, dispatch_secs })
    }
}

/// Private result of one expert's compute: output rows (gather order)
/// plus its contribution to the phase accounting, merged by the caller
/// in deterministic job order.
struct ExpertComputeOut {
    /// `rows.len() * d_model` output values, one row per gathered row
    y: Vec<f32>,
    transfer_secs: f64,
    dispatch_secs: f64,
    invocations: u64,
}

/// Output of one forward pass.
pub struct ForwardOutput {
    /// final hidden states `[1, L, D]` (host values)
    pub hidden: Vec<f32>,
    pub lm_logits: Option<Vec<f32>>,
    pub cls_logits: Option<Vec<f32>>,
    /// per-MoE-layer routing actually used
    pub routing: Vec<RoutingDecision>,
    pub times: PhaseTimes,
}

/// Output of [`ModelRunner::forward_batch`].
pub struct BatchForwardOutput {
    /// per-request outputs, aligned with the input batch; their `times`
    /// are zeroed (see [`ModelRunner::forward_batch`])
    pub outputs: Vec<ForwardOutput>,
    /// batch-aggregate phase breakdown: expert invocations and H2D
    /// transfers are counted once per activated expert per batch
    pub times: PhaseTimes,
}

/// Stack per-request `[1, ...tail]` f32 literals into one `[B, ...tail]`.
fn stack_f32(parts: &[Literal]) -> Result<Literal> {
    let tail = &parts[0].shape()[1..];
    let per: usize = tail.iter().product();
    let mut data = Vec::with_capacity(parts.len() * per);
    for p in parts {
        data.extend_from_slice(p.f32s()?);
    }
    let mut shape = vec![parts.len()];
    shape.extend_from_slice(tail);
    Literal::from_f32s(&shape, data)
}

/// Split one `[B, ...tail]` f32 literal back into `B` `[1, ...tail]`
/// literals (exact value-preserving copies).
fn split_f32(batch: &Literal) -> Result<Vec<Literal>> {
    let b = batch.shape()[0];
    let tail = &batch.shape()[1..];
    let per: usize = tail.iter().product();
    let data = batch.f32s()?;
    let mut shape = vec![1usize];
    shape.extend_from_slice(tail);
    (0..b)
        .map(|i| Literal::from_f32s(&shape, data[i * per..(i + 1) * per].to_vec()))
        .collect()
}

/// Runner-lifetime cache of one transformer block's non-expert weight
/// literals — fetched from the `WeightStore` once at construction, so
/// the per-forward path never formats a tensor name or re-copies a
/// dense weight again.
struct BlockLits {
    ln1_g: Literal,
    ln1_b: Literal,
    wq: Literal,
    bq: Literal,
    wk: Literal,
    bk: Literal,
    wv: Literal,
    bv: Literal,
    wo: Literal,
    bo: Literal,
    ln2_g: Literal,
    ln2_b: Literal,
    /// dense-FFN weights (w1, b1, w2, b2) — `None` on MoE blocks
    ffn: Option<[Literal; 4]>,
    /// router weights — `None` on dense blocks
    wr: Option<Literal>,
}

/// Runner-lifetime cache of the embedding/head weights.
struct HeadLits {
    embed_tok: Literal,
    final_ln_g: Literal,
    final_ln_b: Literal,
    lm_w: Literal,
    lm_b: Literal,
    cls_w: Literal,
    cls_b: Literal,
}

/// Drives one model config at one profile seq-len.
pub struct ModelRunner {
    pub bundle: Arc<ModelBundle>,
    pub profile: String,
    pub seq_len: usize,
    /// worker pool for the per-expert fan-out of each MoE layer
    pool: WorkerPool,
    exe_embed: Arc<Executable>,
    exe_attn: Arc<Executable>,
    exe_dense_ffn: Arc<Executable>,
    exe_moe_ln: Arc<Executable>,
    exe_router: Arc<Executable>,
    exe_combine: Arc<Executable>,
    exe_lm_head: Arc<Executable>,
    exe_cls_head: Arc<Executable>,
    exe_lm_nll: Arc<Executable>,
    exe_expert: BTreeMap<usize, Arc<Executable>>,
    /// per-block weight literals, indexed by block
    blocks: Vec<BlockLits>,
    head: HeadLits,
    /// positional table sliced to seq_len
    pos_lit: Literal,
}

impl ModelRunner {
    pub fn new(bundle: Arc<ModelBundle>, profile: &str) -> Result<Self> {
        Self::with_pool(bundle, profile, WorkerPool::auto())
    }

    /// Construct with an explicit worker-pool width (`WorkerPool::new(1)`
    /// is the fully sequential reference execution).
    pub fn with_pool(bundle: Arc<ModelBundle>, profile: &str, pool: WorkerPool) -> Result<Self> {
        let topo = &bundle.topology;
        let seq_len = topo.seq_len(profile)?;
        let eng = &bundle.engine;
        let l = seq_len;
        let exe_embed = eng.load(&format!("embed_L{l}"))?;
        let exe_attn = eng.load(&format!("attn_L{l}"))?;
        let exe_dense_ffn = eng.load(&format!("dense_ffn_L{l}"))?;
        let exe_moe_ln = eng.load(&format!("moe_ln_L{l}"))?;
        let exe_router = eng.load(&format!("router_L{l}"))?;
        let exe_combine = eng.load(&format!("moe_combine_L{l}"))?;
        let exe_lm_head = eng.load(&format!("lm_head_L{l}"))?;
        let exe_cls_head = eng.load(&format!("cls_head_L{l}"))?;
        let exe_lm_nll = eng.load(&format!("lm_nll_L{l}"))?;
        let mut exe_expert = BTreeMap::new();
        for &b in &topo.buckets {
            exe_expert.insert(b, eng.load(&format!("expert_T{b}"))?);
        }

        // hoist every non-expert weight literal into runner-lifetime
        // caches: the per-forward hot path indexes structs instead of
        // formatting names and re-fetching from the weight store
        let w = |name: String| bundle.weights.literal(&name);
        let mut blocks = Vec::with_capacity(topo.n_blocks);
        for b in 0..topo.n_blocks {
            let part = |p: &str| w(format!("blocks.{b}.{p}"));
            let is_moe = topo.moe_layer_index(b).is_some();
            blocks.push(BlockLits {
                ln1_g: part("ln1_g")?,
                ln1_b: part("ln1_b")?,
                wq: part("wq")?,
                bq: part("bq")?,
                wk: part("wk")?,
                bk: part("bk")?,
                wv: part("wv")?,
                bv: part("bv")?,
                wo: part("wo")?,
                bo: part("bo")?,
                ln2_g: part("ln2_g")?,
                ln2_b: part("ln2_b")?,
                ffn: if is_moe {
                    None
                } else {
                    Some([part("w1")?, part("b1")?, part("w2")?, part("b2")?])
                },
                wr: if is_moe { Some(part("wr")?) } else { None },
            });
        }
        let head = HeadLits {
            embed_tok: w("embed.tok".into())?,
            final_ln_g: w("final_ln_g".into())?,
            final_ln_b: w("final_ln_b".into())?,
            lm_w: w("lm_head.w".into())?,
            lm_b: w("lm_head.b".into())?,
            cls_w: w("cls_head.w".into())?,
            cls_b: w("cls_head.b".into())?,
        };

        // positional slice [L, D]
        let pos_full = bundle.weights.f32_slice("embed.pos")?;
        let d = topo.d_model;
        let pos_lit = literal_from_f32s(&[l, d], &pos_full[..l * d])?;

        Ok(ModelRunner {
            bundle,
            profile: profile.to_string(),
            seq_len,
            pool,
            exe_embed,
            exe_attn,
            exe_dense_ffn,
            exe_moe_ln,
            exe_router,
            exe_combine,
            exe_lm_head,
            exe_cls_head,
            exe_lm_nll,
            exe_expert,
            blocks,
            head,
            pos_lit,
        })
    }

    /// Worker-pool width this runner fans expert invocations out to.
    pub fn pool_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Attention mask for padded ids — delegates to the canonical
    /// [`crate::workload::pad_mask`].
    pub fn mask_of(ids: &[i32]) -> Vec<f32> {
        crate::workload::pad_mask(ids)
    }

    /// Embed a sentence: ids (padded to seq_len) -> [1, L, D] literal.
    pub fn embed(&self, ids: &[i32]) -> Result<Literal> {
        debug_assert_eq!(ids.len(), self.seq_len);
        let ids_lit = literal_i32(&[1, self.seq_len], ids)?;
        let out = self
            .exe_embed
            .run(&[&ids_lit, &self.head.embed_tok, &self.pos_lit])?;
        Ok(out.into_iter().next().unwrap())
    }

    fn run_attn(&self, x: &Literal, mask: &Literal, block: usize) -> Result<Literal> {
        let bl = &self.blocks[block];
        let args: Vec<&Literal> = vec![
            x, mask, &bl.ln1_g, &bl.ln1_b, &bl.wq, &bl.bq, &bl.wk, &bl.bk, &bl.wv, &bl.bv,
            &bl.wo, &bl.bo,
        ];
        Ok(self.exe_attn.run(&args)?.into_iter().next().unwrap())
    }

    fn run_dense_ffn(&self, x: &Literal, block: usize) -> Result<Literal> {
        let bl = &self.blocks[block];
        let ffn = bl
            .ffn
            .as_ref()
            .with_context(|| format!("block {block} has no dense FFN weights"))?;
        let args: Vec<&Literal> =
            vec![x, &bl.ln2_g, &bl.ln2_b, &ffn[0], &ffn[1], &ffn[2], &ffn[3]];
        Ok(self.exe_dense_ffn.run(&args)?.into_iter().next().unwrap())
    }

    fn run_moe_ln(&self, x: &Literal, block: usize) -> Result<Literal> {
        let bl = &self.blocks[block];
        let args: Vec<&Literal> = vec![x, &bl.ln2_g, &bl.ln2_b];
        Ok(self.exe_moe_ln.run(&args)?.into_iter().next().unwrap())
    }

    /// Run the true router on LN'd hidden states -> per-token top-1.
    pub fn run_router(&self, xln: &Literal, block: usize) -> Result<RoutingDecision> {
        let wr = self.blocks[block]
            .wr
            .as_ref()
            .with_context(|| format!("block {block} has no router weights"))?;
        let args: Vec<&Literal> = vec![xln, wr];
        let out = self.exe_router.run(&args)?;
        // outputs: logits [1,L,E], idx i32 [1,L], alpha [1,L]
        let idx = to_i32_vec(&out[1])?;
        let alpha = to_f32_vec(&out[2])?;
        let top1: Vec<usize> = idx.iter().map(|&i| i as usize).collect();
        let assignments = top1
            .iter()
            .zip(alpha.iter())
            .map(|(&e, &a)| vec![(e, a)])
            .collect();
        Ok(RoutingDecision { top1, assignments })
    }

    /// Routing decision from a SiDA hash table for one MoE layer.
    /// `k_used` experts per token, alphas renormalized over the k used
    /// (paper §4: top-1 for SST2, top-3 for MRPC/MultiRC).
    pub fn routing_from_hash(
        &self,
        table: &HashTable,
        moe_layer: usize,
        k_used: usize,
    ) -> RoutingDecision {
        let l = self.seq_len;
        let mut top1 = Vec::with_capacity(l);
        let mut assignments = Vec::with_capacity(l);
        for t in 0..l {
            let mut assign: Vec<(usize, f32)> = (0..k_used.min(table.k))
                .map(|r| {
                    (
                        table.expert_at(t, moe_layer, r),
                        table.alpha_at(t, moe_layer, r),
                    )
                })
                .collect();
            let norm: f32 = assign.iter().map(|(_, a)| *a).sum::<f32>().max(1e-9);
            for pair in assign.iter_mut() {
                pair.1 /= norm;
            }
            // rescale to the hash's top-1 confidence so magnitude tracks
            // the router's alpha (the student softmax approximates it)
            let lead = table.alpha_at(t, moe_layer, 0);
            for pair in assign.iter_mut() {
                pair.1 *= lead;
            }
            top1.push(assign[0].0);
            assignments.push(assign);
        }
        RoutingDecision { top1, assignments }
    }

    /// Execute one packed chunk given its staged weight parts.
    fn dispatch_chunk(
        &self,
        exe: &Executable,
        bucket: usize,
        packed: &[f32],
        parts: &[DeviceBuffer; 4],
    ) -> Result<Vec<Literal>> {
        let d = self.bundle.topology.d_model;
        let x_buf = self.bundle.engine.stage_f32(&[bucket, d], packed)?;
        let bufs: Vec<&DeviceBuffer> = vec![&x_buf, &parts[0], &parts[1], &parts[2], &parts[3]];
        exe.run_buffers(&bufs)
    }

    /// Compute one expert's gathered rows: pack token rows into
    /// bucket-sized chunks (splitting exactly like the historical
    /// recursive dispatcher when rows exceed the largest bucket),
    /// resolve residency through the [`ExpertDispatch`] resolver, and
    /// return the per-row outputs in gather order.  Pure compute — no
    /// shared accumulator is touched, which is what makes this safe to
    /// run on pool threads while preserving bit-identical scatter.
    /// One loop serves every provider variant; only residency
    /// resolution differs (the resolver).
    fn compute_expert_rows<D: ExpertDispatch + ?Sized>(
        &self,
        block: usize,
        expert: usize,
        xlns: &[Vec<f32>],
        rows: &[GatheredRow],
        disp: &D,
        fixed_bucket: bool,
    ) -> Result<ExpertComputeOut> {
        let topo = &self.bundle.topology;
        let d = topo.d_model;
        let key = ExpertKey::new(block, expert);
        let mut out = ExpertComputeOut {
            y: Vec::with_capacity(rows.len() * d),
            transfer_secs: 0.0,
            dispatch_secs: 0.0,
            invocations: 0,
        };
        let mut packed: Vec<f32> = Vec::new();
        let mut start = 0usize;
        while start < rows.len() {
            let remaining = rows.len() - start;
            let bucket = if fixed_bucket {
                topo.bucket_for(self.seq_len)
            } else {
                topo.bucket_for(remaining)
            };
            let take = remaining.min(bucket);
            let chunk = &rows[start..start + take];
            packed.clear();
            packed.resize(bucket * d, 0.0);
            for (r, row) in chunk.iter().enumerate() {
                let src = &xlns[row.item][row.token * d..(row.token + 1) * d];
                packed[r * d..(r + 1) * d].copy_from_slice(src);
            }
            let exe = self
                .exe_expert
                .get(&bucket)
                .with_context(|| format!("no expert artifact for bucket {bucket}"))?;

            let chunk_out = disp.dispatch_chunk(self, key, exe, bucket, &packed)?;
            out.transfer_secs += chunk_out.transfer_secs;
            out.dispatch_secs += chunk_out.dispatch_secs;
            out.invocations += 1;
            let y = to_f32_vec(&chunk_out.result[0])?;
            out.y.extend_from_slice(&y[..take * d]);
            start += take;
        }
        Ok(out)
    }

    /// Cluster dispatch of one MoE layer's jobs: the [`ClusterRouter`]
    /// assigns every job (ascending expert order, so the assignment is
    /// deterministic) to a device holding that expert — weighing lanes
    /// by **dispatch-bucket units** (rows round up to the padded chunks
    /// this method actually executes, so lanes balance real compute) —
    /// the jobs run as **one worker lane per device** on the pool, each
    /// lane resolving residency through its own device's shared cache
    /// (which drives that device's §6 residency ledger), and jobs
    /// computed off the primary device are charged the modeled
    /// cross-device activation transfer.  Returns per-job results in
    /// the original job order, so the caller's scatter (and therefore
    /// the f32 bits) is identical to the single-device path.
    #[allow(clippy::too_many_arguments)]
    fn run_cluster_lanes(
        &self,
        block: usize,
        jobs: &[ExpertJob],
        xlns: &[Vec<f32>],
        router: &ClusterRouter,
        blocking: bool,
        fixed_bucket: bool,
        trace_ids: Option<&[u64]>,
    ) -> Vec<Result<ExpertComputeOut>> {
        let meta: Vec<(usize, usize)> =
            jobs.iter().map(|j| (j.expert, j.rows.len())).collect();
        let assign = router.assign(block, &meta);
        // A device crashing on this batch tick loses its in-flight
        // lanes (DESIGN.md §2.7).  Which jobs fail is decided here,
        // before dispatch, purely from (fault plan, tick, assignment) —
        // fully deterministic, unlike asking mid-execution.
        let lane_failed: Vec<bool> =
            assign.iter().map(|&dev| router.lane_should_fail(dev)).collect();
        let mut per_device: Vec<Vec<usize>> = vec![Vec::new(); router.devices()];
        for (i, &dev) in assign.iter().enumerate() {
            per_device[dev].push(i);
        }
        let lanes: Vec<(usize, Vec<usize>)> = per_device
            .into_iter()
            .enumerate()
            .filter(|(_, idxs)| !idxs.is_empty())
            .collect();
        let lane_outs: Vec<Vec<(usize, Result<ExpertComputeOut>)>> =
            self.pool.run(lanes, |_slot, (device, idxs)| {
                let par = ParProvider::Shared { cache: router.device_cache(device), blocking };
                let t_lane = trace::begin();
                let lane: Vec<(usize, Result<ExpertComputeOut>)> = idxs
                    .iter()
                    .map(|&i| {
                        let job = &jobs[i];
                        let res = self
                            .compute_expert_rows(
                                block, job.expert, xlns, &job.rows, &par, fixed_bucket,
                            )
                            .map(|mut out| {
                                out.transfer_secs += router
                                    .charge_activation_transfer(device, job.rows.len());
                                out
                            });
                        (i, res)
                    })
                    .collect();
                if trace::enabled() {
                    // flow steps tie each request through this lane's
                    // slice (emitted before the span closes so their
                    // timestamps land inside it)
                    if let Some(ids) = trace_ids {
                        let items: BTreeSet<usize> = idxs
                            .iter()
                            .flat_map(|&i| jobs[i].rows.iter().map(|r| r.item))
                            .collect();
                        for item in items {
                            if let Some(&rid) = ids.get(item) {
                                trace::flow('t', rid, trace::device_pid(device));
                            }
                        }
                    }
                    trace::complete(
                        "lane",
                        "cluster",
                        trace::device_pid(device),
                        t_lane,
                        vec![
                            ("block", ArgValue::U(block as u64)),
                            ("jobs", ArgValue::U(idxs.len() as u64)),
                        ],
                    );
                }
                lane
            });
        let mut outs: Vec<Option<Result<ExpertComputeOut>>> =
            (0..jobs.len()).map(|_| None).collect();
        for lane in lane_outs {
            for (i, res) in lane {
                outs[i] = Some(res);
            }
        }
        // Retry-once-on-survivors: recompute each lost job inline on a
        // healthy device the router picks.  Exactly one retry — the
        // survivor is healthy by construction, so it cannot fail on the
        // same tick.  The replacement lands in the same job slot before
        // the caller's ascending-order scatter, and expert math is
        // device-independent, so outputs stay bit-identical; the
        // survivor pays a blocking ensure (it may not hold the expert)
        // plus the activation transfer on the modeled timeline.  Any
        // deadline this recovery blows is shed by the batcher exactly
        // like any other slow batch (the PR 6 SLO rules).
        for (i, job) in jobs.iter().enumerate() {
            if !lane_failed[i] {
                continue;
            }
            let retry_dev =
                router.retry_assignment(block, job.expert, job.rows.len(), assign[i]);
            let par =
                ParProvider::Shared { cache: router.device_cache(retry_dev), blocking: true };
            let t_retry = trace::begin();
            let res = self
                .compute_expert_rows(block, job.expert, xlns, &job.rows, &par, fixed_bucket)
                .map(|mut out| {
                    out.transfer_secs +=
                        router.charge_activation_transfer(retry_dev, job.rows.len());
                    out
                });
            if trace::enabled() {
                if let Some(ids) = trace_ids {
                    let items: BTreeSet<usize> = job.rows.iter().map(|r| r.item).collect();
                    for item in items {
                        if let Some(&rid) = ids.get(item) {
                            trace::flow('t', rid, trace::device_pid(retry_dev));
                        }
                    }
                }
                trace::complete(
                    "lane_retry",
                    "cluster",
                    trace::device_pid(retry_dev),
                    t_retry,
                    vec![
                        ("block", ArgValue::U(block as u64)),
                        ("expert", ArgValue::U(job.expert as u64)),
                        ("failed_device", ArgValue::U(assign[i] as u64)),
                    ],
                );
            }
            outs[i] = Some(res);
        }
        outs.into_iter()
            .map(|o| o.expect("cluster lane left a job without a result"))
            .collect()
    }

    /// Run every job of one MoE layer — concurrently on the worker pool
    /// for the parallel-capable providers, as one lane per modeled
    /// device for `Cluster`, inline for `Cached` — then merge the
    /// outputs into the accumulators **sequentially in ascending job
    /// order**: per-token accumulation order is identical to the fully
    /// sequential path, so outputs are bit-identical at every pool
    /// width and every device count.
    #[allow(clippy::too_many_arguments)]
    fn run_expert_set(
        &self,
        block: usize,
        jobs: &[ExpertJob],
        xlns: &[Vec<f32>],
        y_accs: &mut [Vec<f32>],
        provider: &mut ExpertProvider<'_>,
        fixed_bucket: bool,
        times: &mut PhaseTimes,
        trace_ids: Option<&[u64]>,
    ) -> Result<()> {
        if jobs.is_empty() {
            return Ok(());
        }
        let d = self.bundle.topology.d_model;
        let t_span = trace::begin();
        let t_wall = Instant::now();
        let outs: Vec<Result<ExpertComputeOut>> = match provider {
            ExpertProvider::Cached { cache, blocking } => {
                // single-owner cache: inline, through the same shared
                // chunk loop as every other variant
                let disp = CachedDispatch { cache: RefCell::new(&mut **cache), blocking: *blocking };
                jobs.iter()
                    .map(|job| {
                        self.compute_expert_rows(
                            block, job.expert, xlns, &job.rows, &disp, fixed_bucket,
                        )
                    })
                    .collect()
            }
            ExpertProvider::Cluster { router, blocking } => self.run_cluster_lanes(
                block,
                jobs,
                xlns,
                *router,
                *blocking,
                fixed_bucket,
                trace_ids,
            ),
            other => {
                let par = match &*other {
                    ExpertProvider::AllResident(map) => ParProvider::AllResident(*map),
                    ExpertProvider::Shared { cache, blocking } => {
                        ParProvider::Shared { cache: *cache, blocking: *blocking }
                    }
                    ExpertProvider::HostLiterals => ParProvider::HostLiterals,
                    ExpertProvider::Cached { .. } | ExpertProvider::Cluster { .. } => {
                        unreachable!("handled above")
                    }
                };
                let indices: Vec<usize> = (0..jobs.len()).collect();
                self.pool.run(indices, |_slot, i| {
                    let job = &jobs[i];
                    self.compute_expert_rows(
                        block, job.expert, xlns, &job.rows, &par, fixed_bucket,
                    )
                })
            }
        };
        let wall = t_wall.elapsed().as_secs_f64();
        times.expert_wall_secs += wall;
        if trace::enabled() {
            trace::complete(
                "expert_wall",
                "moe",
                trace::host_pid(),
                t_span,
                vec![
                    ("block", ArgValue::U(block as u64)),
                    ("jobs", ArgValue::U(jobs.len() as u64)),
                    ("secs", ArgValue::F(wall)),
                ],
            );
        }

        let t_scatter_span = trace::begin();
        let t_scatter = Instant::now();
        for (job, out) in jobs.iter().zip(outs) {
            let out = out?;
            times.transfer_secs += out.transfer_secs;
            times.expert_secs += out.dispatch_secs;
            times.expert_invocations += out.invocations;
            for (r, row) in job.rows.iter().enumerate() {
                let dst = &mut y_accs[row.item][row.token * d..(row.token + 1) * d];
                let src = &out.y[r * d..(r + 1) * d];
                for (o, v) in dst.iter_mut().zip(src.iter()) {
                    *o += row.alpha * v;
                }
            }
        }
        let scatter = t_scatter.elapsed().as_secs_f64();
        times.scatter_secs += scatter;
        if trace::enabled() {
            trace::complete(
                "scatter",
                "moe",
                trace::host_pid(),
                t_scatter_span,
                vec![
                    ("block", ArgValue::U(block as u64)),
                    ("secs", ArgValue::F(scatter)),
                ],
            );
        }
        Ok(())
    }

    /// Build the deterministic job list for one layer from the
    /// expert -> rows map (ascending expert order; with `invoke_all`
    /// every expert gets a job, idle experts a zero-alpha placeholder).
    fn jobs_from_union(
        &self,
        mut union: BTreeMap<usize, Vec<GatheredRow>>,
        invoke_all: bool,
    ) -> Vec<ExpertJob> {
        if invoke_all {
            (0..self.bundle.topology.num_experts)
                .map(|expert| ExpertJob {
                    expert,
                    rows: union.remove(&expert).unwrap_or_else(|| {
                        vec![GatheredRow { item: 0, token: 0, alpha: 0.0 }]
                    }),
                })
                .collect()
        } else {
            union
                .into_iter()
                .map(|(expert, rows)| ExpertJob { expert, rows })
                .collect()
        }
    }

    /// Run one MoE layer given a routing decision.  The decision's
    /// alphas are applied host-side during scatter; the combine artifact
    /// adds the residual with alpha=1 on real tokens.  `trace_ids`
    /// carries the request ids for span-tracer flow events (see
    /// [`ForwardHooks::trace_ids`]).
    #[allow(clippy::too_many_arguments)]
    pub fn run_moe_layer(
        &self,
        x: &Literal,
        mask_host: &[f32],
        mask_lit: &Literal,
        block: usize,
        routing: &RoutingDecision,
        provider: &mut ExpertProvider<'_>,
        opts: ForwardOptions,
        times: &mut PhaseTimes,
        trace_ids: Option<&[u64]>,
    ) -> Result<Literal> {
        let topo = &self.bundle.topology;
        let d = topo.d_model;
        let l = self.seq_len;
        let xln = self.run_moe_ln(x, block)?;

        let t_gather_span = trace::begin();
        let t_gather = Instant::now();
        let xln_host = to_f32_vec(&xln)?;
        let mut y_acc = vec![0f32; l * d];
        let mut union: BTreeMap<usize, Vec<GatheredRow>> = BTreeMap::new();
        for (expert, assigns) in routing.tokens_per_expert(mask_host) {
            union.insert(
                expert,
                assigns
                    .iter()
                    .map(|&(t, a)| GatheredRow { item: 0, token: t, alpha: a })
                    .collect(),
            );
        }
        let jobs = self.jobs_from_union(union, opts.invoke_all);
        let gather = t_gather.elapsed().as_secs_f64();
        times.gather_secs += gather;
        if trace::enabled() {
            trace::complete(
                "gather",
                "moe",
                trace::host_pid(),
                t_gather_span,
                vec![
                    ("block", ArgValue::U(block as u64)),
                    ("experts", ArgValue::U(jobs.len() as u64)),
                    ("secs", ArgValue::F(gather)),
                ],
            );
        }

        self.run_expert_set(
            block,
            &jobs,
            std::slice::from_ref(&xln_host),
            std::slice::from_mut(&mut y_acc),
            provider,
            opts.fixed_bucket,
            times,
            trace_ids,
        )?;

        let y_lit = literal_from_f32s(&[1, l, d], &y_acc)?;
        let ones = literal_from_f32s(&[1, l], &vec![1.0f32; l])?;
        let out = self.exe_combine.run(&[x, &y_lit, &ones, mask_lit])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Full forward pass.  `hash_routing` supplies the per-MoE-layer
    /// decision: SiDA reads the hash table; baselines run the router
    /// (passing `None` here runs the router on the fly).
    pub fn forward(
        &self,
        ids: &[i32],
        hash_routing: Option<(&HashTable, usize)>,
        provider: &mut ExpertProvider<'_>,
        opts: ForwardOptions,
    ) -> Result<ForwardOutput> {
        self.forward_hooked(ids, hash_routing, provider, opts, ForwardHooks::default())
    }

    /// [`ModelRunner::forward`] with out-of-band hooks (layer-gate
    /// coupling to a layer-ahead warmer — see [`ForwardHooks`]).
    pub fn forward_hooked(
        &self,
        ids: &[i32],
        hash_routing: Option<(&HashTable, usize)>,
        provider: &mut ExpertProvider<'_>,
        opts: ForwardOptions,
        hooks: ForwardHooks<'_>,
    ) -> Result<ForwardOutput> {
        let topo = &self.bundle.topology;
        if ids.len() != self.seq_len {
            bail!("ids len {} != seq_len {}", ids.len(), self.seq_len);
        }
        let mut times = PhaseTimes::default();
        let mask_host = Self::mask_of(ids);
        let mask_lit = literal_from_f32s(&[1, self.seq_len], &mask_host)?;

        let t0 = Instant::now();
        let mut x = self.embed(ids)?;
        times.dense_secs += t0.elapsed().as_secs_f64();

        let mut routing_used = Vec::new();
        for block in 0..topo.n_blocks {
            let t_attn = Instant::now();
            x = self.run_attn(&x, &mask_lit, block)?;
            times.dense_secs += t_attn.elapsed().as_secs_f64();

            match topo.moe_layer_index(block) {
                None => {
                    let t_ffn = Instant::now();
                    x = self.run_dense_ffn(&x, block)?;
                    times.dense_secs += t_ffn.elapsed().as_secs_f64();
                }
                Some(moe_layer) => {
                    // expert selection
                    let t_sel = Instant::now();
                    let routing = match hash_routing {
                        Some((table, k_used)) => {
                            self.routing_from_hash(table, moe_layer, k_used)
                        }
                        None => {
                            let xln = self.run_moe_ln(&x, block)?;
                            self.run_router(&xln, block)?
                        }
                    };
                    times.selection_secs += t_sel.elapsed().as_secs_f64();

                    // layer gate: wait until the layer-ahead warmer has
                    // staged this layer (measured warm-up stall on the
                    // critical path)
                    if let Some(gate) = hooks.layer_gate {
                        times.stall_secs += gate.begin_layer(moe_layer);
                    }

                    x = self.run_moe_layer(
                        &x,
                        &mask_host,
                        &mask_lit,
                        block,
                        &routing,
                        provider,
                        opts,
                        &mut times,
                        hooks.trace_ids,
                    )?;
                    routing_used.push(routing);
                }
            }
        }

        let mut lm_logits = None;
        let mut cls_logits = None;
        let t_head = Instant::now();
        if opts.want_lm {
            let out = self.exe_lm_head.run(&[
                &x,
                &self.head.final_ln_g,
                &self.head.final_ln_b,
                &self.head.lm_w,
                &self.head.lm_b,
            ])?;
            lm_logits = Some(to_f32_vec(&out[0])?);
        }
        if opts.want_cls {
            let out = self.exe_cls_head.run(&[
                &x,
                &mask_lit,
                &self.head.final_ln_g,
                &self.head.final_ln_b,
                &self.head.cls_w,
                &self.head.cls_b,
            ])?;
            cls_logits = Some(to_f32_vec(&out[0])?);
        }
        times.dense_secs += t_head.elapsed().as_secs_f64();

        let hidden = to_f32_vec(&x)?;
        Ok(ForwardOutput {
            hidden,
            lm_logits,
            cls_logits,
            routing: routing_used,
            times,
        })
    }

    /// Cross-request batched forward pass.
    ///
    /// The dense per-sequence stages (embed, attention, dense FFN,
    /// heads) run for every request — as one stacked `[B, L, ...]`
    /// dispatch per stage when the backend reports
    /// [`batched_entries`](crate::runtime::Backend::batched_entries),
    /// else as a per-request loop — while every MoE layer **gathers the
    /// tokens routed to the same expert across the whole batch and
    /// issues one expert invocation per activated expert**, not one per
    /// request.  The activated experts run concurrently on the runner's
    /// worker pool.  Each expert's residency is ensured (and its H2D
    /// transfer charged) once per batch, which is where the paper's
    /// batch-level amortization of expert traffic comes from.
    ///
    /// Outputs are bit-identical to running [`ModelRunner::forward`] on
    /// each request sequentially: the expert FFN computes packed rows
    /// independently, and per-token accumulation order is preserved
    /// (experts ascending, tokens in sequence order, scattered on the
    /// calling thread after the pool joins).  Per-request `times` in
    /// the returned outputs are zeroed — under shared dispatch
    /// per-request phase attribution is not meaningful; use the
    /// batch-level [`BatchForwardOutput::times`].
    pub fn forward_batch(
        &self,
        items: &[BatchItem<'_>],
        provider: &mut ExpertProvider<'_>,
        opts: ForwardOptions,
    ) -> Result<BatchForwardOutput> {
        self.forward_batch_hooked(items, provider, opts, ForwardHooks::default())
    }

    /// [`ModelRunner::forward_batch`] with out-of-band hooks.
    pub fn forward_batch_hooked(
        &self,
        items: &[BatchItem<'_>],
        provider: &mut ExpertProvider<'_>,
        opts: ForwardOptions,
        hooks: ForwardHooks<'_>,
    ) -> Result<BatchForwardOutput> {
        let topo = &self.bundle.topology;
        let n = items.len();
        anyhow::ensure!(n > 0, "forward_batch: empty batch");
        for it in items {
            if it.ids.len() != self.seq_len {
                bail!("ids len {} != seq_len {}", it.ids.len(), self.seq_len);
            }
        }
        let l = self.seq_len;
        let batched = n > 1 && self.bundle.engine.batched_entries();
        let mut times = PhaseTimes::default();

        let masks: Vec<Vec<f32>> = items.iter().map(|it| Self::mask_of(it.ids)).collect();
        let mask_lits: Vec<Literal> = masks
            .iter()
            .map(|m| literal_from_f32s(&[1, l], m))
            .collect::<Result<_>>()?;
        let mask_stack = if batched {
            let mut flat = Vec::with_capacity(n * l);
            for m in &masks {
                flat.extend_from_slice(m);
            }
            Some(literal_from_f32s(&[n, l], &flat)?)
        } else {
            None
        };

        let t0 = Instant::now();
        let mut xs = self.embed_many(items, batched)?;
        times.dense_secs += t0.elapsed().as_secs_f64();

        let mut routing_used: Vec<Vec<RoutingDecision>> = (0..n).map(|_| Vec::new()).collect();
        for block in 0..topo.n_blocks {
            let t_attn = Instant::now();
            xs = self.attn_many(&xs, &mask_lits, mask_stack.as_ref(), block)?;
            times.dense_secs += t_attn.elapsed().as_secs_f64();

            match topo.moe_layer_index(block) {
                None => {
                    let t_ffn = Instant::now();
                    xs = self.dense_ffn_many(&xs, batched, block)?;
                    times.dense_secs += t_ffn.elapsed().as_secs_f64();
                }
                Some(moe_layer) => {
                    // LN'd hidden states serve both the router (when no
                    // hash table routes) and the expert gather — compute
                    // them once per request per layer
                    let xln_hosts = self.moe_ln_hosts(&xs, batched, block)?;
                    let d = topo.d_model;

                    // per-request expert selection (hash table or router)
                    let t_sel = Instant::now();
                    let mut routings = Vec::with_capacity(n);
                    for (i, it) in items.iter().enumerate() {
                        let routing = match it.hash {
                            Some((table, k_used)) => {
                                self.routing_from_hash(table, moe_layer, k_used)
                            }
                            None => {
                                // rebuilt from the host copy: value-identical
                                // to a fresh moe_ln dispatch
                                let xln = literal_from_f32s(&[1, l, d], &xln_hosts[i])?;
                                self.run_router(&xln, block)?
                            }
                        };
                        routings.push(routing);
                    }
                    times.selection_secs += t_sel.elapsed().as_secs_f64();

                    if let Some(gate) = hooks.layer_gate {
                        times.stall_secs += gate.begin_layer(moe_layer);
                    }

                    let t_gather_span = trace::begin();
                    let t_gather = Instant::now();
                    let mut y_accs: Vec<Vec<f32>> =
                        (0..n).map(|_| vec![0f32; l * d]).collect();
                    let mut union: BTreeMap<usize, Vec<GatheredRow>> = BTreeMap::new();
                    for (i, routing) in routings.iter().enumerate() {
                        for (expert, assigns) in routing.tokens_per_expert(&masks[i]) {
                            union.entry(expert).or_default().extend(
                                assigns
                                    .iter()
                                    .map(|&(t, a)| GatheredRow { item: i, token: t, alpha: a }),
                            );
                        }
                    }
                    let jobs = self.jobs_from_union(union, opts.invoke_all);
                    let gather = t_gather.elapsed().as_secs_f64();
                    times.gather_secs += gather;
                    if trace::enabled() {
                        trace::complete(
                            "gather",
                            "moe",
                            trace::host_pid(),
                            t_gather_span,
                            vec![
                                ("block", ArgValue::U(block as u64)),
                                ("experts", ArgValue::U(jobs.len() as u64)),
                                ("batch", ArgValue::U(n as u64)),
                                ("secs", ArgValue::F(gather)),
                            ],
                        );
                    }

                    self.run_expert_set(
                        block,
                        &jobs,
                        &xln_hosts,
                        &mut y_accs,
                        provider,
                        opts.fixed_bucket,
                        &mut times,
                        hooks.trace_ids,
                    )?;

                    xs = self.combine_many(&xs, &y_accs, &mask_lits, mask_stack.as_ref())?;
                    for (i, routing) in routings.into_iter().enumerate() {
                        routing_used[i].push(routing);
                    }
                }
            }
        }

        // heads per request
        let t_head = Instant::now();
        let mut outputs = Vec::with_capacity(n);
        for i in 0..n {
            let x = &xs[i];
            let mut lm_logits = None;
            let mut cls_logits = None;
            if opts.want_lm {
                let out = self.exe_lm_head.run(&[
                    x,
                    &self.head.final_ln_g,
                    &self.head.final_ln_b,
                    &self.head.lm_w,
                    &self.head.lm_b,
                ])?;
                lm_logits = Some(to_f32_vec(&out[0])?);
            }
            if opts.want_cls {
                let out = self.exe_cls_head.run(&[
                    x,
                    &mask_lits[i],
                    &self.head.final_ln_g,
                    &self.head.final_ln_b,
                    &self.head.cls_w,
                    &self.head.cls_b,
                ])?;
                cls_logits = Some(to_f32_vec(&out[0])?);
            }
            outputs.push(ForwardOutput {
                hidden: to_f32_vec(x)?,
                lm_logits,
                cls_logits,
                routing: std::mem::take(&mut routing_used[i]),
                times: PhaseTimes::default(),
            });
        }
        times.dense_secs += t_head.elapsed().as_secs_f64();
        Ok(BatchForwardOutput { outputs, times })
    }

    /// Embed every request of a batch (one stacked dispatch when the
    /// backend supports batched entries, else per request).
    fn embed_many(&self, items: &[BatchItem<'_>], batched: bool) -> Result<Vec<Literal>> {
        if batched {
            let l = self.seq_len;
            let mut ids = Vec::with_capacity(items.len() * l);
            for it in items {
                ids.extend_from_slice(it.ids);
            }
            let ids_lit = literal_i32(&[items.len(), l], &ids)?;
            let out = self
                .exe_embed
                .run(&[&ids_lit, &self.head.embed_tok, &self.pos_lit])?;
            split_f32(&out[0])
        } else {
            items.iter().map(|it| self.embed(it.ids)).collect()
        }
    }

    fn attn_many(
        &self,
        xs: &[Literal],
        mask_lits: &[Literal],
        mask_stack: Option<&Literal>,
        block: usize,
    ) -> Result<Vec<Literal>> {
        match mask_stack {
            Some(mask) => {
                let stacked = stack_f32(xs)?;
                split_f32(&self.run_attn(&stacked, mask, block)?)
            }
            None => xs
                .iter()
                .zip(mask_lits.iter())
                .map(|(x, m)| self.run_attn(x, m, block))
                .collect(),
        }
    }

    fn dense_ffn_many(&self, xs: &[Literal], batched: bool, block: usize) -> Result<Vec<Literal>> {
        if batched {
            let stacked = stack_f32(xs)?;
            split_f32(&self.run_dense_ffn(&stacked, block)?)
        } else {
            xs.iter().map(|x| self.run_dense_ffn(x, block)).collect()
        }
    }

    /// LN'd hidden states of every request as host buffers — the gather
    /// source for the batch-wide expert dispatch.
    fn moe_ln_hosts(&self, xs: &[Literal], batched: bool, block: usize) -> Result<Vec<Vec<f32>>> {
        if batched {
            let stacked = stack_f32(xs)?;
            let host = to_f32_vec(&self.run_moe_ln(&stacked, block)?)?;
            let per = host.len() / xs.len();
            Ok(host.chunks(per).map(|c| c.to_vec()).collect())
        } else {
            xs.iter()
                .map(|x| to_f32_vec(&self.run_moe_ln(x, block)?))
                .collect()
        }
    }

    fn combine_many(
        &self,
        xs: &[Literal],
        y_accs: &[Vec<f32>],
        mask_lits: &[Literal],
        mask_stack: Option<&Literal>,
    ) -> Result<Vec<Literal>> {
        let l = self.seq_len;
        let d = self.bundle.topology.d_model;
        match mask_stack {
            Some(mask) => {
                let n = xs.len();
                let stacked = stack_f32(xs)?;
                let mut y = Vec::with_capacity(n * l * d);
                for acc in y_accs {
                    y.extend_from_slice(acc);
                }
                let y_lit = literal_from_f32s(&[n, l, d], &y)?;
                let ones = literal_from_f32s(&[n, l], &vec![1.0f32; n * l])?;
                let out = self.exe_combine.run(&[&stacked, &y_lit, &ones, mask])?;
                split_f32(&out[0])
            }
            None => {
                let ones = literal_from_f32s(&[1, l], &vec![1.0f32; l])?;
                xs.iter()
                    .zip(y_accs.iter())
                    .zip(mask_lits.iter())
                    .map(|((x, acc), m)| {
                        let y_lit = literal_from_f32s(&[1, l, d], acc)?;
                        let out = self.exe_combine.run(&[x, &y_lit, &ones, m])?;
                        Ok(out.into_iter().next().unwrap())
                    })
                    .collect()
            }
        }
    }

    /// Per-sentence LM NLL + token count via the lm_nll artifact.
    pub fn lm_nll(&self, lm_logits: &[f32], ids: &[i32]) -> Result<(f64, f64)> {
        let l = self.seq_len;
        let v = self.bundle.topology.vocab;
        let mask = Self::mask_of(ids);
        let logits_lit = literal_from_f32s(&[1, l, v], lm_logits)?;
        let ids_lit = literal_i32(&[1, l], ids)?;
        let mask_lit = literal_from_f32s(&[1, l], &mask)?;
        let out = self.exe_lm_nll.run(&[&logits_lit, &ids_lit, &mask_lit])?;
        let nll = to_f32_vec(&out[0])?[0] as f64;
        let cnt = to_f32_vec(&out[1])?[0] as f64;
        Ok((nll, cnt))
    }

    /// Stage every expert of every MoE layer on device (baseline setup).
    pub fn stage_all_experts(&self) -> Result<HashMap<ExpertKey, [DeviceBuffer; 4]>> {
        let topo = &self.bundle.topology;
        let mut map = HashMap::new();
        for &block in &topo.moe_blocks {
            for expert in 0..topo.num_experts {
                map.insert(
                    ExpertKey::new(block, expert),
                    crate::runtime::stage_expert_parts(
                        &self.bundle.engine,
                        &self.bundle.weights,
                        block,
                        expert,
                    )?,
                );
            }
        }
        Ok(map)
    }
}
