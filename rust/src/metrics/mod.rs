//! Serving metrics: latency distributions, throughput, phase breakdowns,
//! and the markdown/CSV reporters the benches print paper tables with.

pub mod histogram;
pub mod report;

pub use histogram::LatencyHistogram;
pub use report::Table;

use crate::model::PhaseTimes;

/// Aggregate over one serving run (one method x model x dataset cell).
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: u64,
    pub wall_secs: f64,
    pub latency: LatencyHistogram,
    pub phases: PhaseTimes,
    /// hash-building thread: total build time (overlapped, not critical path)
    pub hash_build_secs: f64,
    /// peak simulated device bytes (Fig 8)
    pub peak_device_bytes: usize,
    /// device budget in effect
    pub budget_bytes: usize,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub blocking_misses: u64,
    pub evictions: u64,
    pub transferred_bytes: u64,
}

impl ServeStats {
    /// Cache hit fraction, `None` when the run produced no cache traffic
    /// (all-resident baselines) — distinct from a true 0% hit rate.
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            None
        } else {
            Some(self.cache_hits as f64 / total as f64)
        }
    }

    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.requests as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    pub fn tokens_per_sec(&self, tokens: u64) -> f64 {
        if self.wall_secs > 0.0 {
            tokens as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut s = ServeStats::default();
        s.requests = 10;
        s.wall_secs = 2.0;
        assert!((s.throughput() - 5.0).abs() < 1e-9);
        assert!((s.tokens_per_sec(100) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn zero_wall_is_safe() {
        let s = ServeStats::default();
        assert_eq!(s.throughput(), 0.0);
    }

    #[test]
    fn hit_rate_distinguishes_no_traffic_from_all_misses() {
        let mut s = ServeStats::default();
        assert_eq!(s.hit_rate(), None);
        s.cache_misses = 4;
        assert_eq!(s.hit_rate(), Some(0.0));
        s.cache_hits = 12;
        assert!((s.hit_rate().unwrap() - 0.75).abs() < 1e-12);
    }
}
