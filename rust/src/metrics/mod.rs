//! Serving metrics: latency distributions, throughput, phase breakdowns,
//! and the markdown/CSV reporters the benches print paper tables with.

pub mod histogram;
pub mod report;

pub use histogram::LatencyHistogram;
pub use report::Table;

use crate::model::PhaseTimes;

/// Aggregate over one serving run (one method x model x dataset cell).
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: u64,
    pub wall_secs: f64,
    pub latency: LatencyHistogram,
    pub phases: PhaseTimes,
    /// hash-building thread: total build time (overlapped, not critical path)
    pub hash_build_secs: f64,
    /// peak simulated device bytes (Fig 8)
    pub peak_device_bytes: usize,
    /// device budget in effect
    pub budget_bytes: usize,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub blocking_misses: u64,
    pub evictions: u64,
    pub transferred_bytes: u64,
}

impl ServeStats {
    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.requests as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    pub fn tokens_per_sec(&self, tokens: u64) -> f64 {
        if self.wall_secs > 0.0 {
            tokens as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut s = ServeStats::default();
        s.requests = 10;
        s.wall_secs = 2.0;
        assert!((s.throughput() - 5.0).abs() < 1e-9);
        assert!((s.tokens_per_sec(100) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn zero_wall_is_safe() {
        let s = ServeStats::default();
        assert_eq!(s.throughput(), 0.0);
    }
}
