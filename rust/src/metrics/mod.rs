//! Serving metrics: latency distributions, throughput, phase breakdowns,
//! and the markdown/CSV reporters the benches print paper tables with.

pub mod histogram;
pub mod report;

pub use histogram::LatencyHistogram;
pub use report::Table;

use crate::model::PhaseTimes;

/// Aggregate over one serving run (one method x model x dataset cell).
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    pub requests: u64,
    /// forward passes issued; `requests` in batch-1 serving, fewer when
    /// cross-request batching coalesces several requests per forward
    pub batches: u64,
    pub wall_secs: f64,
    pub latency: LatencyHistogram,
    pub phases: PhaseTimes,
    /// hash-building thread: total build time (overlapped, not critical path)
    pub hash_build_secs: f64,
    /// peak simulated device bytes (Fig 8)
    pub peak_device_bytes: usize,
    /// device budget in effect
    pub budget_bytes: usize,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub blocking_misses: u64,
    pub evictions: u64,
    pub transferred_bytes: u64,
    /// total modeled H2D transfer seconds, both timelines
    pub modeled_transfer_secs: f64,
    /// the share of `modeled_transfer_secs` spent on the prefetch
    /// timeline, hidden behind compute (request-ahead stage +
    /// layer-ahead warmer); the critical path pays only
    /// [`ServeStats::exposed_transfer_secs`]
    pub overlapped_transfer_secs: f64,
    /// the §6 GPU→RAM→SSD ladder, read from the cache-driven residency
    /// ledger: per-tier byte occupancy, promotions per hop, and the
    /// ladder-seconds attribution of `modeled_transfer_secs` (aggregated
    /// over every device cache in cluster mode)
    pub hierarchy: crate::memory::HierarchyStats,
    /// per-device breakdown when the run served across a modeled device
    /// fleet (`--devices N`): memory, cache traffic, row loads,
    /// cross-device transfer totals.  `None` for single-device runs.
    pub cluster: Option<crate::cluster::ClusterStats>,
    /// end-to-end latency of served interactive-class requests only
    pub latency_interactive: LatencyHistogram,
    /// end-to-end latency of served batch-class requests only
    pub latency_batch: LatencyHistogram,
    /// interactive requests dropped at batch-cut time with a blown
    /// deadline (open-loop serving only)
    pub shed: u64,
    /// requests rejected at admission: queue full
    pub rejected: u64,
    /// requests rejected at admission: predicted queue delay already
    /// exceeded the class deadline
    pub rejected_slo: u64,
    /// interactive requests offered (served + shed + rejected), the
    /// SLO-attainment denominator
    pub interactive_offered: u64,
    /// served interactive requests that completed within their deadline
    pub slo_attained: u64,
    /// modeled staging seconds still queued on the shared
    /// [`crate::experts::BandwidthWindow`] at snapshot time — transfer
    /// work admitted by the EDF scheduler but not yet drained by
    /// compute-layer advances
    pub prefetch_backlog_secs: f64,
    /// backlog seconds carried (not discarded) across `reset_stats`
    /// epoch boundaries — the drain-or-carry conservation guarantee
    pub prefetch_carried_backlog_secs: f64,
    /// fetches admitted into the bandwidth window by the EDF scheduler
    pub prefetch_admitted: u64,
    /// speculative fetches deferred because their prediction confidence
    /// was too low to spend contended window bandwidth on
    pub prefetch_deferred: u64,
    /// fraction of drain capacity offered by compute-layer advances
    /// that the window actually consumed; `None` before any drain
    pub prefetch_window_utilization: Option<f64>,
}

impl ServeStats {
    /// Mean requests per formed batch, `None` before any batch ran.
    pub fn mean_batch_size(&self) -> Option<f64> {
        if self.batches == 0 {
            None
        } else {
            Some(self.requests as f64 / self.batches as f64)
        }
    }

    /// Simulated H2D bytes moved per request — the amortization metric
    /// cross-request batching improves (each expert is charged once per
    /// batch instead of once per request).
    pub fn transferred_bytes_per_request(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.transferred_bytes as f64 / self.requests as f64
        }
    }

    /// Cache hit fraction, `None` when the run produced no cache traffic
    /// (all-resident baselines) — distinct from a true 0% hit rate.
    pub fn hit_rate(&self) -> Option<f64> {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            None
        } else {
            Some(self.cache_hits as f64 / total as f64)
        }
    }

    /// Total tier-ladder seconds charged onto the modeled-transfer
    /// timeline (RAM-hop + SSD-ladder promotions) — the same seconds as
    /// `modeled_transfer_secs`, attributed by source tier.
    pub fn ladder_secs(&self) -> f64 {
        self.hierarchy.ladder_secs()
    }

    /// Modeled transfer seconds left on the critical path after overlap.
    pub fn exposed_transfer_secs(&self) -> f64 {
        crate::memory::exposed_transfer_secs(
            self.modeled_transfer_secs,
            self.overlapped_transfer_secs,
        )
    }

    /// Modeled per-request latency: the phases' critical path (dense +
    /// selection + gather + pooled expert wall + scatter + measured
    /// layer-gate stalls) plus the exposed (non-overlapped) modeled
    /// transfer, per request.  This is
    /// the regression metric the perf-trajectory JSON tracks: pooled
    /// expert execution shrinks the expert wall, layer-ahead prefetch
    /// shrinks exposed transfer, and neither can regress silently.
    /// `None` before any request was served.  Most meaningful with
    /// `real_sleep = false` (virtual transfer cost): with real sleeps
    /// the stalls are already inside the measured walls.
    ///
    /// Known model limits: (a) prefetch-timeline fetches queue on the
    /// shared [`BandwidthWindow`](crate::experts::BandwidthWindow), so
    /// a burst of prefetches is credited only up to the modeled
    /// bandwidth window that actually existed before each fetch's
    /// deadline (the uncredited share surfaces as exposed transfer) —
    /// but the window is one shared modeled link, so when several
    /// threads charge it concurrently the per-fetch credit split
    /// depends on arrival interleaving (the total stays bounded by the
    /// offered window); (b) a *blocking* fetch's
    /// physical staging wall (microseconds at repro scale) lands inside
    /// `expert_wall_secs` while its *modeled* seconds (milliseconds at
    /// paper scale) are billed as exposed transfer — a small double
    /// count on paths that fetch on the critical path, which slightly
    /// flatters prefetching.  Within one mode both biases are constant,
    /// so trajectory *comparisons* remain valid.
    pub fn modeled_request_secs(&self) -> Option<f64> {
        if self.requests == 0 {
            None
        } else {
            Some(
                (self.phases.critical_path_secs() + self.exposed_transfer_secs())
                    / self.requests as f64,
            )
        }
    }

    /// Record one served request's end-to-end latency under its SLO
    /// class: the per-class histogram, and — for interactive requests —
    /// the attainment counters.  The all-requests `latency` histogram
    /// is recorded separately by the serving loop (it predates classes
    /// and keeps its exact semantics).
    pub fn record_class(&mut self, class: &crate::workload::SloClass, latency_secs: f64) {
        match class.deadline_secs() {
            Some(deadline) => {
                self.latency_interactive.record(latency_secs);
                self.interactive_offered += 1;
                if latency_secs <= deadline {
                    self.slo_attained += 1;
                }
            }
            None => self.latency_batch.record(latency_secs),
        }
    }

    /// Fraction of offered interactive requests that completed within
    /// their deadline (shed and rejected ones count against it).
    /// `None` when the run offered no interactive traffic.
    pub fn slo_attainment(&self) -> Option<f64> {
        if self.interactive_offered == 0 {
            None
        } else {
            Some(self.slo_attained as f64 / self.interactive_offered as f64)
        }
    }

    pub fn throughput(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.requests as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    pub fn tokens_per_sec(&self, tokens: u64) -> f64 {
        if self.wall_secs > 0.0 {
            tokens as f64 / self.wall_secs
        } else {
            0.0
        }
    }
}

/// Counters for the cross-request batch former behind the TCP server:
/// how many batches formed, how large they were, and the per-request
/// latency attribution (time waiting for the batch to form vs time in
/// the shared forward pass).
#[derive(Debug, Default, Clone)]
pub struct BatchingStats {
    /// batches the shared worker served
    pub batches: u64,
    /// requests carried by those batches
    pub batched_requests: u64,
    /// per-request seconds between admission and the batch being cut
    pub batching_delay: LatencyHistogram,
    /// per-batch forward-pass seconds (hash build + inference)
    pub inference: LatencyHistogram,
    /// interactive requests shed at batch-cut time (deadline blown)
    pub shed: u64,
    /// end-to-end latency (queue + infer) of served interactive requests
    pub latency_interactive: LatencyHistogram,
    /// end-to-end latency (queue + infer) of served batch-lane requests
    pub latency_batch: LatencyHistogram,
    /// served interactive requests that made their deadline
    pub slo_attained: u64,
    /// served interactive requests that missed their deadline (shed
    /// requests are counted via `shed`, not here)
    pub slo_missed: u64,
    /// connections reaped after idling past `--conn-timeout`
    pub conn_timeouts: u64,
}

impl BatchingStats {
    /// Record one served batch: its per-request batching delays and the
    /// shared inference time.
    pub fn observe_batch(&mut self, batching_delays: &[f64], infer_secs: f64) {
        self.batches += 1;
        self.batched_requests += batching_delays.len() as u64;
        for &d in batching_delays {
            self.batching_delay.record(d);
        }
        self.inference.record(infer_secs);
    }

    /// Record one served request's end-to-end latency under its class.
    pub fn observe_request(&mut self, class: &crate::workload::SloClass, total_secs: f64) {
        match class.deadline_secs() {
            Some(deadline) => {
                self.latency_interactive.record(total_secs);
                if total_secs <= deadline {
                    self.slo_attained += 1;
                } else {
                    self.slo_missed += 1;
                }
            }
            None => self.latency_batch.record(total_secs),
        }
    }

    /// Count requests shed at cut time with a blown deadline.
    pub fn observe_shed(&mut self, n: usize) {
        self.shed += n as u64;
    }

    /// Mean requests per batch, `None` before any batch was served.
    pub fn mean_batch_size(&self) -> Option<f64> {
        if self.batches == 0 {
            None
        } else {
            Some(self.batched_requests as f64 / self.batches as f64)
        }
    }

    /// SLO attainment over served + shed interactive traffic.
    pub fn slo_attainment(&self) -> Option<f64> {
        let offered = self.slo_attained + self.slo_missed + self.shed;
        if offered == 0 {
            None
        } else {
            Some(self.slo_attained as f64 / offered as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut s = ServeStats::default();
        s.requests = 10;
        s.wall_secs = 2.0;
        assert!((s.throughput() - 5.0).abs() < 1e-9);
        assert!((s.tokens_per_sec(100) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn zero_wall_is_safe() {
        let s = ServeStats::default();
        assert_eq!(s.throughput(), 0.0);
    }

    #[test]
    fn hit_rate_distinguishes_no_traffic_from_all_misses() {
        let mut s = ServeStats::default();
        assert_eq!(s.hit_rate(), None);
        s.cache_misses = 4;
        assert_eq!(s.hit_rate(), Some(0.0));
        s.cache_hits = 12;
        assert!((s.hit_rate().unwrap() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn modeled_request_latency_accounts_exposed_transfer_only() {
        let mut s = ServeStats::default();
        assert_eq!(s.modeled_request_secs(), None);
        s.requests = 4;
        s.phases.dense_secs = 0.4;
        s.phases.expert_wall_secs = 0.2;
        s.modeled_transfer_secs = 1.0;
        s.overlapped_transfer_secs = 0.9;
        // (0.4 + 0.2 + (1.0 - 0.9)) / 4
        assert!((s.modeled_request_secs().unwrap() - 0.175).abs() < 1e-12);
        // full overlap: only compute remains
        s.overlapped_transfer_secs = 1.0;
        assert!((s.modeled_request_secs().unwrap() - 0.15).abs() < 1e-12);
        // imperfect overlap shows up as a measured gate stall
        s.phases.stall_secs = 0.2;
        assert!((s.modeled_request_secs().unwrap() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn ladder_secs_reports_hierarchy_attribution() {
        let mut s = ServeStats::default();
        s.hierarchy.ram_promote_secs = 0.25;
        s.hierarchy.ssd_promote_secs = 0.5;
        assert!((s.ladder_secs() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn batch_size_and_per_request_transfers() {
        let mut s = ServeStats::default();
        assert_eq!(s.mean_batch_size(), None);
        assert_eq!(s.transferred_bytes_per_request(), 0.0);
        s.requests = 12;
        s.batches = 3;
        s.transferred_bytes = 600;
        assert!((s.mean_batch_size().unwrap() - 4.0).abs() < 1e-12);
        assert!((s.transferred_bytes_per_request() - 50.0).abs() < 1e-12);
    }

    #[test]
    fn per_class_recording_and_attainment() {
        use crate::workload::SloClass;
        let mut s = ServeStats::default();
        assert_eq!(s.slo_attainment(), None);
        let fast = SloClass::Interactive { deadline_secs: 0.1 };
        s.record_class(&fast, 0.05); // attained
        s.record_class(&fast, 0.50); // missed
        s.record_class(&SloClass::Batch, 9.0);
        // a shed interactive request counts against attainment
        s.shed += 1;
        s.interactive_offered += 1;
        assert_eq!(s.latency_interactive.len(), 2);
        assert_eq!(s.latency_batch.len(), 1);
        assert_eq!(s.interactive_offered, 3);
        assert!((s.slo_attainment().unwrap() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn batching_stats_per_class() {
        use crate::workload::SloClass;
        let mut b = BatchingStats::default();
        assert_eq!(b.slo_attainment(), None);
        let fast = SloClass::Interactive { deadline_secs: 0.1 };
        b.observe_request(&fast, 0.05);
        b.observe_request(&fast, 0.20);
        b.observe_request(&SloClass::Batch, 1.0);
        b.observe_shed(2);
        assert_eq!(b.latency_interactive.len(), 2);
        assert_eq!(b.latency_batch.len(), 1);
        assert_eq!((b.slo_attained, b.slo_missed, b.shed), (1, 1, 2));
        assert!((b.slo_attainment().unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn batching_stats_observe() {
        let mut b = BatchingStats::default();
        assert_eq!(b.mean_batch_size(), None);
        b.observe_batch(&[0.001, 0.002, 0.003], 0.010);
        b.observe_batch(&[0.004], 0.005);
        assert_eq!(b.batches, 2);
        assert_eq!(b.batched_requests, 4);
        assert!((b.mean_batch_size().unwrap() - 2.0).abs() < 1e-12);
        assert_eq!(b.batching_delay.len(), 4);
        assert_eq!(b.inference.len(), 2);
        assert!((b.inference.mean() - 0.0075).abs() < 1e-12);
    }
}
