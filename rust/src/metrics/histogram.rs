//! Latency histogram with exact quantiles (sample set is small enough
//! to keep all observations; no HDR approximation needed at our scale).

#[derive(Debug, Default, Clone)]
pub struct LatencyHistogram {
    samples: Vec<f64>,
    sorted: bool,
}

impl LatencyHistogram {
    pub fn record(&mut self, secs: f64) {
        self.samples.push(secs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // total_cmp: a stray NaN sample sorts to the end instead of
            // aborting every stats report via partial_cmp().unwrap()
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Quantile by nearest-rank; `q` is clamped to [0,1] (NaN -> 0).
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        let rank = ((q * self.samples.len() as f64).ceil() as usize).max(1) - 1;
        self.samples[rank.min(self.samples.len() - 1)]
    }

    pub fn p50(&mut self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&mut self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&mut self) -> f64 {
        self.quantile(0.99)
    }

    pub fn p999(&mut self) -> f64 {
        self.quantile(0.999)
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            0.0
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.first().copied().unwrap_or(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        self.samples.last().copied().unwrap_or(0.0)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// The raw observations (unsorted unless a quantile was taken).
    /// The observability publisher mirrors these into the bucketed
    /// registry histogram (`crate::obs`).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        self.samples.extend_from_slice(&other.samples);
        self.sorted = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_nearest_rank() {
        let mut h = LatencyHistogram::default();
        for i in 1..=100 {
            h.record(i as f64);
        }
        assert_eq!(h.p50(), 50.0);
        assert_eq!(h.p95(), 95.0);
        assert_eq!(h.p99(), 99.0);
        assert_eq!(h.quantile(1.0), 100.0);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 100.0);
    }

    #[test]
    fn empty_histogram() {
        let mut h = LatencyHistogram::default();
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn single_sample() {
        let mut h = LatencyHistogram::default();
        h.record(3.5);
        assert_eq!(h.p50(), 3.5);
        assert_eq!(h.p99(), 3.5);
        assert_eq!(h.mean(), 3.5);
    }

    #[test]
    fn merge_combines() {
        let mut a = LatencyHistogram::default();
        let mut b = LatencyHistogram::default();
        a.record(1.0);
        b.record(3.0);
        a.merge(&b);
        assert_eq!(a.len(), 2);
        assert!((a.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn nan_sample_does_not_panic_and_sorts_last() {
        let mut h = LatencyHistogram::default();
        h.record(2.0);
        h.record(f64::NAN);
        h.record(1.0);
        // must not panic; finite samples still order correctly
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.p50(), 2.0);
        assert!(h.quantile(1.0).is_nan(), "NaN sorts to the end under total_cmp");
    }

    #[test]
    fn quantile_input_clamped() {
        let mut h = LatencyHistogram::default();
        for i in 1..=10 {
            h.record(i as f64);
        }
        assert_eq!(h.quantile(-0.5), 1.0);
        assert_eq!(h.quantile(1.5), 10.0);
        assert_eq!(h.quantile(f64::NAN), 1.0);
    }

    #[test]
    fn p999_tracks_extreme_tail() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        assert_eq!(h.p99(), 990.0);
        assert_eq!(h.p999(), 999.0);
    }

    #[test]
    fn unsorted_then_quantile_after_record() {
        let mut h = LatencyHistogram::default();
        h.record(5.0);
        h.record(1.0);
        assert_eq!(h.p50(), 1.0);
        h.record(0.5);
        assert_eq!(h.min(), 0.5);
    }
}
