//! Table reporter: aligned text tables (the benches print paper-style
//! rows) + CSV emission for plotting.

use std::fmt::Write as _;

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line =
            |cells: &[String], w: &[usize]| -> String {
                let mut s = String::from("| ");
                for (i, c) in cells.iter().enumerate() {
                    let _ = write!(s, "{:<width$} | ", c, width = w[i]);
                }
                s.trim_end().to_string()
            };
        let _ = writeln!(out, "{}", line(&self.headers, &w));
        let mut sep = String::from("|");
        for width in &w {
            let _ = write!(sep, "{}|", "-".repeat(width + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &w));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| -> String {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write CSV beside the bench output for plotting.
    pub fn save_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// Format an optional ratio as a percentage, `n/a` when undefined
/// (e.g. a cache hit rate with zero traffic).
pub fn fmt_rate(r: Option<f64>) -> String {
    match r {
        Some(v) => format!("{:.1}%", 100.0 * v),
        None => "n/a".to_string(),
    }
}

/// Format seconds adaptively (ms below 1s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Format bytes as GB/MB.
pub fn fmt_bytes(b: usize) -> String {
    let gb = b as f64 / 1e9;
    if gb >= 1.0 {
        format!("{gb:.2}GB")
    } else {
        format!("{:.1}MB", b as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("demo", &["model", "throughput"]);
        t.row(vec!["switch8".into(), "12.5".into()]);
        t.row(vec!["switch256".into(), "3.1".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| switch8"));
        assert!(s.contains("| switch256"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["has,comma".into()]);
        assert!(t.to_csv().contains("\"has,comma\""));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(0.0123), "12.30ms");
        assert_eq!(fmt_secs(2.5), "2.500s");
        assert_eq!(fmt_bytes(2_500_000_000), "2.50GB");
        assert_eq!(fmt_bytes(500_000), "0.5MB");
        assert_eq!(fmt_rate(Some(0.375)), "37.5%");
        assert_eq!(fmt_rate(None), "n/a");
    }
}
