//! Minimal in-repo stand-in for the `log` facade.
//!
//! Provides the subset used by this workspace: the five level macros,
//! [`Level`]/[`LevelFilter`], [`Metadata`]/[`Record`], the [`Log`] trait
//! and [`set_logger`]/[`set_max_level`].  Level ordering matches the real
//! crate: `Error < Warn < Info < Debug < Trace`, so `level <= max`
//! filtering code ports unchanged.

use std::fmt;
use std::sync::atomic::{AtomicPtr, AtomicUsize, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(usize)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        // honor width/alignment ({:5} in logger impls)
        f.pad(s)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(usize)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Debug, Clone)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn new(level: Level, target: &'a str) -> Self {
        Metadata { level, target }
    }

    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

#[derive(Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn new(metadata: Metadata<'a>, args: fmt::Arguments<'a>) -> Self {
        Record { metadata, args }
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

struct LoggerSlot(&'static dyn Log);

static LOGGER: AtomicPtr<LoggerSlot> = AtomicPtr::new(std::ptr::null_mut());
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let slot = Box::into_raw(Box::new(LoggerSlot(logger)));
    match LOGGER.compare_exchange(
        std::ptr::null_mut(),
        slot,
        Ordering::SeqCst,
        Ordering::SeqCst,
    ) {
        Ok(_) => Ok(()),
        Err(_) => {
            // lost the race; free our slot and report
            drop(unsafe { Box::from_raw(slot) });
            Err(SetLoggerError(()))
        }
    }
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::SeqCst);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::SeqCst) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// The installed logger, if any (used by the macros).
pub fn logger() -> Option<&'static dyn Log> {
    let p = LOGGER.load(Ordering::SeqCst);
    if p.is_null() {
        None
    } else {
        Some(unsafe { (*p).0 })
    }
}

/// Macro backend: dispatch one record to the installed logger.
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::SeqCst) {
        return;
    }
    if let Some(l) = logger() {
        let metadata = Metadata::new(level, target);
        if l.enabled(&metadata) {
            l.log(&Record::new(metadata, args));
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_order_matches_log_crate() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Trace > Level::Debug);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Trace);
        assert_eq!(max_level(), LevelFilter::Trace);
    }

    #[test]
    fn macros_are_safe_without_logger() {
        // no logger installed in this test binary: must be a no-op
        crate::trace!("t {}", 1);
        crate::debug!("d");
        crate::info!("i");
        crate::warn!("w");
        crate::error!("e");
    }
}
