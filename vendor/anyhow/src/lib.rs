//! Minimal in-repo stand-in for the `anyhow` crate.
//!
//! The build is fully hermetic (no crates.io access), so this shim
//! provides the subset of anyhow the workspace actually uses:
//!
//! * [`Error`]: an opaque error value with a context chain.  `Display`
//!   prints the outermost context; `{:#}` (alternate) prints the whole
//!   chain `outer: ...: root`, matching anyhow's rendering that the CLI
//!   relies on (`eprintln!("error: {e:#}")`).
//! * [`Result<T>`] alias.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros (format-string forms).
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * `From<E: std::error::Error + Send + Sync + 'static>` so `?`
//!   converts io/parse/domain errors exactly like the real crate.
//!
//! Like the real anyhow, `Error` deliberately does NOT implement
//! `std::error::Error` — that is what keeps the blanket `From` impl
//! coherent.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

pub struct Error {
    /// context strings, outermost first
    chain: Vec<String>,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

/// Plain-message root error (what `anyhow!` produces).
struct MessageError(String);

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error { chain: Vec::new(), source: Box::new(MessageError(msg.to_string())) }
    }

    pub fn new<E: StdError + Send + Sync + 'static>(err: E) -> Self {
        Error { chain: Vec::new(), source: Box::new(err) }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root) error.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cur: &(dyn StdError + 'static) = self.source.as_ref();
        while let Some(next) = cur.source() {
            cur = next;
        }
        cur
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for c in &self.chain {
                write!(f, "{c}: ")?;
            }
            write!(f, "{}", self.source)
        } else if let Some(outer) = self.chain.first() {
            f.write_str(outer)
        } else {
            write!(f, "{}", self.source)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in &self.chain {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.source)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::new(err)
    }
}

/// Context extension for `Result` and `Option` (anyhow-compatible).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_chains_and_formats() {
        let e: Error = Error::new(io_err()).context("inner").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_string(), "three is right out");
        assert_eq!(f(11).unwrap_err().to_string(), "too big: 11");
        let e = anyhow!("v={}", 7);
        assert_eq!(e.to_string(), "v=7");
    }

    #[test]
    fn root_cause_walks() {
        let e = Error::new(io_err()).context("c");
        assert_eq!(e.root_cause().to_string(), "gone");
    }
}
