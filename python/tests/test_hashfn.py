"""Hash-function tests: forward shapes, TKD/CE losses, hit-rate metric,
pallas/ref agreement, serving-entry consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import hashfn
from compile.configs import HASH_CONFIG, MODEL_CONFIGS

CFG = MODEL_CONFIGS["switch8"]
HC = HASH_CONFIG


@pytest.fixture(scope="module")
def hp():
    return hashfn.init_hash_params(CFG, HC, seed=0)


def emb(seed=0, b=2, l=16):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, l, CFG.d_model)), jnp.float32)


def test_forward_shape(hp):
    out = hashfn.hash_forward(hp, emb(), CFG, HC)
    assert out.shape == (2, 16, CFG.num_moe_layers, CFG.num_experts)


def test_pallas_path_matches_ref_path(hp):
    e = emb(1)
    ref_out = hashfn.hash_forward(hp, e, CFG, HC, use_pallas=False)
    pallas_out = hashfn.hash_forward(hp, e, CFG, HC, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(pallas_out), np.asarray(ref_out), rtol=5e-5, atol=5e-5
    )


def test_tkd_loss_zero_when_student_equals_teacher(hp):
    rng = np.random.default_rng(2)
    t = jnp.asarray(rng.normal(size=(2, 8, 2, CFG.num_experts)), jnp.float32)
    mask = jnp.ones((2, 8), jnp.float32)
    loss = hashfn.tkd_loss(t, t, mask, HC.kd_top_t)
    assert float(loss) < 1e-6


def test_tkd_loss_positive_for_mismatch(hp):
    rng = np.random.default_rng(3)
    t = jnp.asarray(rng.normal(size=(2, 8, 2, CFG.num_experts)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(2, 8, 2, CFG.num_experts)), jnp.float32)
    mask = jnp.ones((2, 8), jnp.float32)
    assert float(hashfn.tkd_loss(s, t, mask, HC.kd_top_t)) > 0.0


def test_tkd_truncation_ignores_tail():
    """Student's values OUTSIDE the teacher's top-T must not affect TKD."""
    rng = np.random.default_rng(4)
    e = 16
    t = jnp.asarray(rng.normal(size=(1, 4, 1, e)), jnp.float32)
    s1 = jnp.asarray(rng.normal(size=(1, 4, 1, e)), jnp.float32)
    top_t = 4
    # perturb student logits on indices NOT in teacher top-4
    order = np.argsort(-np.asarray(t), axis=-1)
    s2 = np.asarray(s1).copy()
    tail = order[..., top_t:]
    np.put_along_axis(s2, tail, np.asarray(s1)[0, 0, 0, 0] + 123.0, axis=-1)
    mask = jnp.ones((1, 4), jnp.float32)
    l1 = hashfn.tkd_loss(s1, t, mask, top_t)
    l2 = hashfn.tkd_loss(jnp.asarray(s2), t, mask, top_t)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_ce_loss_decreases_with_correct_prediction():
    e = CFG.num_experts
    tid = jnp.zeros((1, 4, 2), jnp.int32)
    mask = jnp.ones((1, 4), jnp.float32)
    good = jnp.zeros((1, 4, 2, e), jnp.float32).at[..., 0].set(10.0)
    bad = jnp.zeros((1, 4, 2, e), jnp.float32).at[..., 1].set(10.0)
    assert float(hashfn.ce_loss(good, tid, mask)) < float(hashfn.ce_loss(bad, tid, mask))


def test_hits_at_k_bounds_and_monotonicity():
    rng = np.random.default_rng(5)
    s = jnp.asarray(rng.normal(size=(2, 8, 2, CFG.num_experts)), jnp.float32)
    tid = jnp.asarray(rng.integers(0, CFG.num_experts, size=(2, 8, 2)), jnp.int32)
    mask = jnp.ones((2, 8), jnp.float32)
    h1 = float(hashfn.hits_at_k(s, tid, mask, k=1))
    h3 = float(hashfn.hits_at_k(s, tid, mask, k=3))
    hk = float(hashfn.hits_at_k(s, tid, mask, k=CFG.num_experts))
    assert 0.0 <= h1 <= h3 <= hk
    assert abs(hk - 1.0) < 1e-6  # k=E always hits


def test_hits_respects_mask():
    s = jnp.zeros((1, 2, 1, 4), jnp.float32).at[0, 0, 0, 2].set(5.0)
    tid = jnp.asarray([[[2], [3]]], jnp.int32)  # token0 correct, token1 wrong
    full = jnp.asarray([[1.0, 1.0]], jnp.float32)
    only0 = jnp.asarray([[1.0, 0.0]], jnp.float32)
    assert abs(float(hashfn.hits_at_k(s, tid, full, k=1)) - 0.5) < 1e-6
    assert abs(float(hashfn.hits_at_k(s, tid, only0, k=1)) - 1.0) < 1e-6


def test_hash_loss_gradients_flow(hp):
    """Every hash parameter must receive a nonzero gradient."""
    rng = np.random.default_rng(6)
    e = emb(7, b=2, l=8)
    t_logits = jnp.asarray(
        rng.normal(size=(2, 8, CFG.num_moe_layers, CFG.num_experts)), jnp.float32
    )
    t_idx = jnp.argmax(t_logits, -1).astype(jnp.int32)
    mask = jnp.ones((2, 8), jnp.float32)
    grads = jax.grad(
        lambda p: hashfn.hash_loss(p, e, t_logits, t_idx, mask, CFG, HC)[0]
    )(hp)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    nonzero = sum(float(jnp.sum(jnp.abs(g))) > 0 for g in leaves)
    assert nonzero == len(leaves), f"only {nonzero}/{len(leaves)} grads nonzero"


def test_entry_hash_topk_consistent_with_forward(hp):
    """The serving entry's sort-based top-k must agree with the softmax
    of hash_forward (same params, same ids)."""
    entry = hashfn.make_entry_hash(CFG, HC)
    rng = np.random.default_rng(8)
    L = 12
    ids = jnp.asarray(rng.integers(3, CFG.vocab, size=(1, L)), jnp.int32)
    tok = jnp.asarray(rng.normal(size=(CFG.vocab, CFG.d_model)) * 0.1, jnp.float32)
    pos = jnp.asarray(rng.normal(size=(L, CFG.d_model)) * 0.1, jnp.float32)
    idx, p = entry(
        ids, tok, pos, hp["compress_w"], hp["compress_b"],
        hp["lstm"][0]["wx"], hp["lstm"][0]["wh"], hp["lstm"][0]["b"],
        hp["lstm"][1]["wx"], hp["lstm"][1]["wh"], hp["lstm"][1]["b"],
        hp["out_w"], hp["out_b"],
    )
    assert idx.shape == (1, L, CFG.num_moe_layers, HC.top_k)
    emb_in = jnp.take(tok, ids, axis=0) + pos[None]
    logits = hashfn.hash_forward(hp, emb_in, CFG, HC)
    probs = jax.nn.softmax(logits, -1)
    want_idx = np.argsort(-np.asarray(probs), axis=-1)[..., : HC.top_k]
    np.testing.assert_array_equal(np.asarray(idx), want_idx)
    # probabilities descending
    p_np = np.asarray(p)
    assert (np.diff(p_np, axis=-1) <= 1e-6).all()
