"""Corpus generator + serializer tests."""

import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import serialize
from compile.configs import DATASET_PROFILES, MODEL_CONFIGS
from compile.data import BOS, CONTENT_START, EOS, PAD, SyntheticCorpus

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pname", list(DATASET_PROFILES))
def test_sentence_structure(pname):
    prof = DATASET_PROFILES[pname]
    corpus = SyntheticCorpus(prof, 256, seed=0)
    batch = corpus.eval_batch(8)
    assert batch.ids.shape == (8, prof.seq_len)
    for b in range(8):
        n = batch.lengths[b]
        assert batch.ids[b, 0] == BOS
        assert batch.ids[b, n - 1] == EOS
        assert (batch.ids[b, n:] == PAD).all()
        assert (batch.ids[b, 1 : n - 1] >= CONTENT_START).all()
        assert (batch.mask[b] == (batch.ids[b] != PAD)).all()


def test_lengths_in_profile_band():
    prof = DATASET_PROFILES["mrpc"]
    corpus = SyntheticCorpus(prof, 256, seed=1)
    for batch in corpus.batches(16, 3):
        body = batch.lengths - 2
        assert (body >= prof.min_len).all()
        assert (body <= min(prof.max_len, prof.seq_len - 2)).all()


def test_determinism_and_salt_independence():
    prof = DATASET_PROFILES["sst2"]
    a = SyntheticCorpus(prof, 256, seed=7).eval_batch(4, salt=5)
    b = SyntheticCorpus(prof, 256, seed=7).eval_batch(4, salt=5)
    c = SyntheticCorpus(prof, 256, seed=7).eval_batch(4, salt=6)
    np.testing.assert_array_equal(a.ids, b.ids)
    assert not np.array_equal(a.ids, c.ids)


def test_topic_clustering_dominates():
    prof = DATASET_PROFILES["sst2"]
    corpus = SyntheticCorpus(prof, 256, seed=3)
    batch = corpus.eval_batch(16)
    band = corpus.band
    hits = 0
    total = 0
    for b in range(16):
        lo = CONTENT_START + batch.labels[b] * band
        body = batch.ids[b, 1 : batch.lengths[b] - 1]
        hits += ((body >= lo) & (body < lo + band)).sum()
        total += len(body)
    assert hits / total > 0.6  # topic_frac=0.75 minus global-draw overlap


# ---------------------------------------------------------------------------
# serializer
# ---------------------------------------------------------------------------

@given(
    shapes=st.lists(
        st.tuples(st.integers(1, 5), st.integers(1, 7)), min_size=1, max_size=6
    ),
    seed=st.integers(0, 1000),
)
def test_write_weights_roundtrip(tmp_path_factory, shapes, seed):
    rng = np.random.default_rng(seed)
    tensors = [
        (f"t{i}", rng.normal(size=s).astype(np.float32)) for i, s in enumerate(shapes)
    ]
    d = tmp_path_factory.mktemp("ser")
    manifest = serialize.write_weights(str(d), tensors)
    blob = open(os.path.join(d, "weights.bin"), "rb").read()
    assert len(blob) == manifest["total_bytes"]
    for rec, (name, arr) in zip(manifest["tensors"], tensors):
        assert rec["name"] == name
        assert rec["offset"] % serialize.ALIGN == 0
        got = np.frombuffer(
            blob[rec["offset"] : rec["offset"] + rec["nbytes"]], np.float32
        ).reshape(rec["shape"])
        np.testing.assert_array_equal(got, arr)


def test_flatten_model_params_expert_granularity():
    from compile import model

    cfg = MODEL_CONFIGS["switch8"]
    params = model.init_params(cfg, seed=0)
    flat = dict(serialize.flatten_model_params(params))
    # per-expert addressability — the unit of offloading
    for b in cfg.moe_blocks:
        for e in range(cfg.num_experts):
            for part in ("w1", "b1", "w2", "b2"):
                assert f"blocks.{b}.expert.{e}.{part}" in flat
    assert flat["blocks.1.expert.0.w1"].shape == (cfg.d_model, cfg.d_ff)
    assert "embed.tok" in flat and "lm_head.w" in flat
    # router stays a separate (offloadable) tensor
    assert f"blocks.{cfg.moe_blocks[0]}.wr" in flat


def test_manifest_json_is_valid(tmp_path):
    rng = np.random.default_rng(0)
    serialize.write_weights(str(tmp_path), [("a", rng.normal(size=(3,)).astype(np.float32))])
    manifest = json.load(open(tmp_path / "manifest.json"))
    assert manifest["version"] == 1
    assert manifest["tensors"][0]["dtype"] == "f32"
