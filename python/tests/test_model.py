"""L2 model tests: shapes, MoE dispatch semantics, losses, forced routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import MODEL_CONFIGS, ModelConfig
from compile.data import SyntheticCorpus, PAD
from compile.configs import DATASET_PROFILES

CFG = MODEL_CONFIGS["switch8"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    corpus = SyntheticCorpus(DATASET_PROFILES["sst2"], CFG.vocab, seed=0)
    return corpus.eval_batch(4)


def test_forward_shapes(params, batch):
    out = model.forward(params, jnp.asarray(batch.ids), jnp.asarray(batch.mask), CFG)
    B, L = batch.ids.shape
    assert out["lm_logits"].shape == (B, L, CFG.vocab)
    assert out["cls_logits"].shape == (B, CFG.n_classes)
    assert len(out["router_logits"]) == CFG.num_moe_layers
    assert out["router_logits"][0].shape == (B, L, CFG.num_experts)
    assert out["router_idx"][0].shape == (B, L)
    assert out["embedded"].shape == (B, L, CFG.d_model)


def test_router_idx_is_argmax_of_logits(params, batch):
    out = model.forward(params, jnp.asarray(batch.ids), jnp.asarray(batch.mask), CFG)
    for lg, idx in zip(out["router_logits"], out["router_idx"]):
        np.testing.assert_array_equal(np.argmax(np.asarray(lg), -1), np.asarray(idx))


def test_moe_single_expert_equivalence():
    """With E=1 the MoE layer must equal alpha * dense expert + residual."""
    cfg = ModelConfig(name="tiny1", num_experts=1, n_blocks=2, moe_blocks=(1,))
    p = model.init_params(cfg, seed=1)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    mask = jnp.ones((2, 8), jnp.float32)
    blk = p["blocks"][1]
    y, logits, idx, alpha, _ = model.moe_ffn_train(blk, x, mask, cfg)
    assert bool(jnp.all(idx == 0))
    np.testing.assert_allclose(np.asarray(alpha), 1.0, rtol=1e-6)
    xln = model.layer_norm(x, blk["ln2_g"], blk["ln2_b"])
    ex = blk["experts"]
    manual = x + (jnp.maximum(xln @ ex["w1"][0] + ex["b1"][0], 0) @ ex["w2"][0] + ex["b2"][0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(manual), rtol=1e-4, atol=1e-4)


def test_forced_routing_matches_router_when_forced_to_router(params, batch):
    """forward_forced_routing with the router's own decisions must equal
    the standard forward — the Rust SiDA-path equivalence golden."""
    ids = jnp.asarray(batch.ids)
    mask = jnp.asarray(batch.mask)
    out = model.forward(params, ids, mask, CFG)
    f_idx = jnp.stack(out["router_idx"], axis=0)
    f_alpha = jnp.stack(out["router_alpha"], axis=0)
    out2 = model.forward_forced_routing(params, ids, mask, CFG, f_idx, f_alpha)
    np.testing.assert_allclose(
        np.asarray(out["lm_logits"]), np.asarray(out2["lm_logits"]), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(out["cls_logits"]), np.asarray(out2["cls_logits"]), rtol=1e-4, atol=1e-4
    )


def test_pad_tokens_do_not_change_masked_loss(params):
    """Extending padding must not change the masked LM loss."""
    corpus = SyntheticCorpus(DATASET_PROFILES["sst2"], CFG.vocab, seed=3)
    b = corpus.eval_batch(2)
    ids = np.asarray(b.ids).copy()
    mask = np.asarray(b.mask)
    out1 = model.forward(params, jnp.asarray(ids), jnp.asarray(mask), CFG)
    l1 = model.lm_loss(out1["lm_logits"], jnp.asarray(ids), jnp.asarray(mask))
    # garbage in padded region, mask unchanged
    ids2 = ids.copy()
    pad_region = mask == 0.0
    ids2[pad_region] = PAD
    out2 = model.forward(params, jnp.asarray(ids2), jnp.asarray(mask), CFG)
    l2 = model.lm_loss(out2["lm_logits"], jnp.asarray(ids2), jnp.asarray(mask))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_aux_loss_penalizes_imbalance():
    """Perfectly balanced routing gives aux ~= 1; collapse gives ~= E."""
    cfg = MODEL_CONFIGS["switch8"]
    e = cfg.num_experts
    # construct probs/idx directly via the formula
    n = 800
    mask = jnp.ones((1, n), jnp.float32)
    balanced_idx = jnp.asarray(np.arange(n) % e, jnp.int32)[None]
    onehot = jax.nn.one_hot(balanced_idx, e)
    f_e = jnp.mean(onehot, axis=(0, 1))
    aux_balanced = e * jnp.sum(f_e * f_e)  # probs == empirical freq here
    assert abs(float(aux_balanced) - 1.0) < 1e-5
    collapsed_idx = jnp.zeros((1, n), jnp.int32)
    onehot = jax.nn.one_hot(collapsed_idx, e)
    f_e = jnp.mean(onehot, axis=(0, 1))
    aux_collapsed = e * jnp.sum(f_e * f_e)
    assert abs(float(aux_collapsed) - e) < 1e-5
    _ = mask


def test_loss_fn_finite_and_decreasing_tendency(params, batch):
    loss, parts = model.loss_fn(
        params, jnp.asarray(batch.ids), jnp.asarray(batch.mask),
        jnp.asarray(batch.labels), CFG,
    )
    assert np.isfinite(float(loss))
    assert float(parts["lm"]) > 0
    assert float(parts["aux"]) >= 1.0 - 1e-3  # load-balance lower bound


def test_entry_embed_matches_model_embed(params, batch):
    ids = jnp.asarray(batch.ids[:1])
    want = model.embed(params, ids)
    (got,) = model.entry_embed(
        ids, params["embed"]["tok"], params["embed"]["pos"][: ids.shape[1]]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_entry_chain_matches_full_forward(params, batch):
    """Drive the sliced entries exactly like the Rust coordinator does
    (with router-true routing, adaptive=dense math) and compare the final
    states to the monolithic forward."""
    cfg = CFG
    ids = jnp.asarray(batch.ids[:1])
    mask = jnp.asarray(batch.mask[:1])
    L = ids.shape[1]
    want = model.forward(params, ids, mask, cfg)

    (x,) = model.entry_embed(ids, params["embed"]["tok"], params["embed"]["pos"][:L])
    attn = model.make_entry_attn(cfg)
    m = 0
    for i, blk in enumerate(params["blocks"]):
        (x,) = attn(
            x, mask, blk["ln1_g"], blk["ln1_b"], blk["wq"], blk["bq"],
            blk["wk"], blk["bk"], blk["wv"], blk["bv"], blk["wo"], blk["bo"],
        )
        if i in cfg.moe_blocks:
            (xln,) = model.entry_moe_ln(x, blk["ln2_g"], blk["ln2_b"])
            logits, idx, alpha = model.entry_router(xln, blk["wr"])
            np.testing.assert_array_equal(
                np.asarray(idx[0]), np.asarray(want["router_idx"][m][0])
            )
            # per-expert invocation: pack tokens, run expert, scatter
            y = np.zeros((1, L, cfg.d_model), np.float32)
            xln_np = np.asarray(xln[0])
            idx_np = np.asarray(idx[0])
            alpha_np = np.asarray(alpha[0])
            mask_np = np.asarray(mask[0])
            ex = blk["experts"]
            expert_fn = model.make_entry_expert(64)
            for e in sorted(set(idx_np[mask_np > 0].tolist())):
                rows = [t for t in range(L) if idx_np[t] == e and mask_np[t] > 0]
                packed = np.zeros((64, cfg.d_model), np.float32)
                for r, t in enumerate(rows):
                    packed[r] = xln_np[t]
                (out,) = expert_fn(
                    jnp.asarray(packed), ex["w1"][e], ex["b1"][e], ex["w2"][e], ex["b2"][e]
                )
                out = np.asarray(out)
                for r, t in enumerate(rows):
                    y[0, t] += alpha_np[t] * out[r]
            ones = jnp.ones((1, L), jnp.float32)
            (x,) = model.entry_moe_combine(x, jnp.asarray(y), ones, mask)
            m += 1
        else:
            (x,) = model.entry_dense_ffn(
                x, blk["ln2_g"], blk["ln2_b"], blk["w1"], blk["b1"], blk["w2"], blk["b2"]
            )
    (lm,) = model.entry_lm_head(
        x, params["final_ln_g"], params["final_ln_b"],
        params["lm_head"]["w"], params["lm_head"]["b"],
    )
    np.testing.assert_allclose(
        np.asarray(lm), np.asarray(want["lm_logits"][:1]), rtol=2e-3, atol=2e-3
    )
