"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/seeds per the testing strategy (DESIGN.md §6);
`assert_allclose` is THE correctness signal for the serving artifacts,
since the same kernels lower into the AOT HLO.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import expert_ffn, lstm_cell, router_top1, sparse_attention
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# expert FFN
# ---------------------------------------------------------------------------

@given(
    t=st.sampled_from([4, 16, 64, 128, 256]),
    d=st.sampled_from([8, 32, 64]),
    f=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_matches_ref(t, d, f, seed):
    rng = np.random.default_rng(seed)
    x, w1, w2 = arr(rng, t, d), arr(rng, d, f), arr(rng, f, d)
    b1, b2 = arr(rng, f), arr(rng, d)
    got = expert_ffn(x, w1, b1, w2, b2)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_expert_ffn_tiled_equals_single_block():
    rng = np.random.default_rng(0)
    x, w1, w2 = arr(rng, 256, 64), arr(rng, 64, 128), arr(rng, 128, 64)
    b1, b2 = arr(rng, 128), arr(rng, 64)
    np.testing.assert_allclose(
        expert_ffn(x, w1, b1, w2, b2, block_t=64),
        expert_ffn(x, w1, b1, w2, b2, block_t=256),
        rtol=1e-5,
        atol=1e-5,
    )


def test_expert_ffn_zero_rows_passthrough_bias():
    """Zero-padded rows produce relu(b1)@w2+b2 — the packing convention
    the Rust coordinator relies on (it never scatters padded rows)."""
    rng = np.random.default_rng(1)
    w1, w2 = arr(rng, 8, 16), arr(rng, 16, 8)
    b1, b2 = arr(rng, 16), arr(rng, 8)
    x = jnp.zeros((4, 8), jnp.float32)
    got = expert_ffn(x, w1, b1, w2, b2)
    want = jnp.maximum(b1, 0.0) @ w2 + b2
    np.testing.assert_allclose(got, jnp.broadcast_to(want, (4, 8)), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

@given(
    t=st.sampled_from([4, 32, 128]),
    d=st.sampled_from([16, 64]),
    e=st.sampled_from([4, 8, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_router_matches_ref(t, d, e, seed):
    rng = np.random.default_rng(seed)
    x, wr = arr(rng, t, d), arr(rng, d, e)
    gl, gi, ga = router_top1(x, wr)
    wl, wi, wa = ref.router_top1_ref(x, wr)
    np.testing.assert_allclose(gl, wl, rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(gi, wi)
    np.testing.assert_allclose(ga, wa, rtol=2e-5, atol=2e-5)


def test_router_alpha_is_softmax_prob():
    rng = np.random.default_rng(2)
    x, wr = arr(rng, 16, 8), arr(rng, 8, 4)
    _, idx, alpha = router_top1(x, wr)
    assert bool(jnp.all(alpha > 0.0)) and bool(jnp.all(alpha <= 1.0))
    # top-1 of softmax has prob >= 1/E
    assert bool(jnp.all(alpha >= 1.0 / 4 - 1e-6))


# ---------------------------------------------------------------------------
# sparsemax / sparse attention
# ---------------------------------------------------------------------------

@given(
    l=st.sampled_from([2, 8, 32, 96]),
    h=st.sampled_from([4, 16, 48]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sparse_attention_matches_ref(l, h, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, l, h)
    np.testing.assert_allclose(
        sparse_attention(x), ref.sparse_attention_ref(x), rtol=2e-5, atol=2e-5
    )


@given(seed=st.integers(0, 2**31 - 1), l=st.sampled_from([2, 5, 17, 64]))
def test_sparsemax_on_simplex(seed, l):
    rng = np.random.default_rng(seed)
    z = arr(rng, 7, l) * 3.0
    p = ref.sparsemax_ref(z)
    np.testing.assert_allclose(jnp.sum(p, axis=-1), 1.0, rtol=1e-5, atol=1e-5)
    assert bool(jnp.all(p >= 0.0))


def test_sparsemax_is_sparse_for_peaked_input():
    z = jnp.asarray([[10.0, 0.0, 0.0, 0.0]], jnp.float32)
    p = ref.sparsemax_ref(z)
    np.testing.assert_allclose(p, [[1.0, 0.0, 0.0, 0.0]], atol=1e-6)


def test_sparsemax_uniform_input_uniform_output():
    z = jnp.ones((1, 8), jnp.float32)
    p = ref.sparsemax_ref(z)
    np.testing.assert_allclose(p, np.full((1, 8), 1 / 8), atol=1e-6)


def test_sparsemax_matches_softmax_limit_ordering():
    """sparsemax preserves the argmax of the input."""
    rng = np.random.default_rng(3)
    z = arr(rng, 16, 10)
    p = ref.sparsemax_ref(z)
    np.testing.assert_array_equal(jnp.argmax(p, -1), jnp.argmax(z, -1))


def test_sparsemax_custom_vjp_matches_finite_difference():
    rng = np.random.default_rng(4)
    z = np.asarray(rng.normal(size=(6,)), np.float32)

    def f(z):
        return jnp.sum(ref.sparsemax_ref(z) ** 2)

    g = jax.grad(f)(jnp.asarray(z))
    eps = 1e-3
    for i in range(6):
        zp, zm = z.copy(), z.copy()
        zp[i] += eps
        zm[i] -= eps
        fd = (f(jnp.asarray(zp)) - f(jnp.asarray(zm))) / (2 * eps)
        np.testing.assert_allclose(g[i], fd, rtol=2e-2, atol=2e-3)


# ---------------------------------------------------------------------------
# LSTM cell
# ---------------------------------------------------------------------------

@given(
    b=st.sampled_from([1, 4, 16]),
    i=st.sampled_from([8, 48]),
    h=st.sampled_from([8, 48]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lstm_cell_matches_ref(b, i, h, seed):
    rng = np.random.default_rng(seed)
    x, hh, cc = arr(rng, b, i), arr(rng, b, h), arr(rng, b, h)
    wx, wh, bias = arr(rng, i, 4 * h), arr(rng, h, 4 * h), arr(rng, 4 * h)
    gh, gc = lstm_cell(x, hh, cc, wx, wh, bias)
    wh_, wc_ = ref.lstm_cell_ref(x, hh, cc, wx, wh, bias)
    np.testing.assert_allclose(gh, wh_, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(gc, wc_, rtol=2e-5, atol=2e-5)


def test_lstm_cell_state_bounded():
    """|h| <= 1 by construction (tanh o sigmoid gating)."""
    rng = np.random.default_rng(5)
    x = arr(rng, 8, 16) * 10
    h = arr(rng, 8, 12)
    c = arr(rng, 8, 12)
    wx, wh, b = arr(rng, 16, 48), arr(rng, 12, 48), arr(rng, 48)
    h2, _ = lstm_cell(x, h, c, wx, wh, b)
    assert bool(jnp.all(jnp.abs(h2) <= 1.0 + 1e-6))
