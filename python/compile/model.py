"""L2: Switch-Transformer-style decoder-only LM in JAX.

Two code paths share one parameter PyTree:

* **Training path** (`forward`, `loss_fn`): pure-jnp math (kernels/ref.py
  semantics), gather-based top-1 MoE dispatch — O(tokens), independent of
  the expert count, so training switch256 on CPU stays cheap.
* **Serving entry points** (`entry_*`): shape-specialized functions with
  *weights as runtime arguments*, lowered by aot.py to HLO text.  The
  expert FFN entry uses the Pallas kernel (kernels/moe.py).  Per-expert
  weights stay runtime args because the whole point of SiDA is that the
  Rust coordinator decides which expert weights are resident where.

Architecture (stand-in for Switch-base, DESIGN.md §2): token+pos
embedding, `n_blocks` pre-LN blocks (causal MHA + FFN), FFN replaced by a
Switch MoE layer on `moe_blocks`, final LN, LM head, mean-pool classifier
head.
"""

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, MAX_SEQ_LEN
from .kernels import ref

Params = Dict


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    rng = np.random.default_rng(seed)
    d, f, v, e = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.num_experts

    def dense(shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
        return jnp.asarray(rng.normal(0.0, scale, size=shape), jnp.float32)

    def zeros(shape):
        return jnp.zeros(shape, jnp.float32)

    def ones(shape):
        return jnp.ones(shape, jnp.float32)

    blocks = []
    for i in range(cfg.n_blocks):
        blk = {
            "ln1_g": ones((d,)), "ln1_b": zeros((d,)),
            "wq": dense((d, d)), "bq": zeros((d,)),
            "wk": dense((d, d)), "bk": zeros((d,)),
            "wv": dense((d, d)), "bv": zeros((d,)),
            "wo": dense((d, d)), "bo": zeros((d,)),
            "ln2_g": ones((d,)), "ln2_b": zeros((d,)),
        }
        if i in cfg.moe_blocks:
            blk["wr"] = dense((d, e), scale=0.02)
            blk["experts"] = {
                "w1": jnp.asarray(rng.normal(0, 1 / np.sqrt(d), size=(e, d, f)), jnp.float32),
                "b1": zeros((e, f)),
                "w2": jnp.asarray(rng.normal(0, 1 / np.sqrt(f), size=(e, f, d)), jnp.float32),
                "b2": zeros((e, d)),
            }
        else:
            blk["w1"] = dense((d, f))
            blk["b1"] = zeros((f,))
            blk["w2"] = dense((f, d))
            blk["b2"] = zeros((d,))
        blocks.append(blk)

    return {
        "embed": {"tok": dense((v, d), scale=0.02), "pos": dense((MAX_SEQ_LEN, d), scale=0.02)},
        "blocks": blocks,
        "final_ln_g": ones((d,)), "final_ln_b": zeros((d,)),
        "lm_head": {"w": dense((d, v)), "b": zeros((v,))},
        "cls_head": {"w": dense((d, cfg.n_classes)), "b": zeros((cfg.n_classes,))},
    }


# --------------------------------------------------------------------------
# shared math
# --------------------------------------------------------------------------

def layer_norm(x, g, b, eps: float = 1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def embed(params: Params, ids):
    """ids: i32 [B, L] -> [B, L, D] (token + positional)."""
    tok = jnp.take(params["embed"]["tok"], ids, axis=0)
    pos = params["embed"]["pos"][: ids.shape[1]][None, :, :]
    return tok + pos


def attention(blk: Params, x, mask, n_heads: int):
    """Pre-LN causal multi-head attention with pad masking + residual.

    x: [B, L, D], mask: f32 [B, L] (1.0 = real token).
    """
    bsz, L, d = x.shape
    hd = d // n_heads
    xln = layer_norm(x, blk["ln1_g"], blk["ln1_b"])
    q = (xln @ blk["wq"] + blk["bq"]).reshape(bsz, L, n_heads, hd)
    k = (xln @ blk["wk"] + blk["bk"]).reshape(bsz, L, n_heads, hd)
    v = (xln @ blk["wv"] + blk["bv"]).reshape(bsz, L, n_heads, hd)
    scores = jnp.einsum("blhe,bmhe->bhlm", q, k) / np.sqrt(hd)
    causal = jnp.tril(jnp.ones((L, L), jnp.float32))
    bias = (causal[None, None] * mask[:, None, None, :] - 1.0) * 1e9
    w = jax.nn.softmax(scores + bias, axis=-1)
    o = jnp.einsum("bhlm,bmhe->blhe", w, v).reshape(bsz, L, d)
    return x + o @ blk["wo"] + blk["bo"]


def dense_ffn(blk: Params, x):
    xln = layer_norm(x, blk["ln2_g"], blk["ln2_b"])
    return x + ref.expert_ffn_ref(xln, blk["w1"], blk["b1"], blk["w2"], blk["b2"])


def moe_ffn_train(blk: Params, x, mask, cfg: ModelConfig):
    """Gather-based top-1 Switch MoE layer (training path).

    Returns (y, router_logits [B,L,E], idx [B,L], alpha [B,L], aux_loss).
    Cost is independent of E: each token gathers its own expert's weights.
    """
    xln = layer_norm(x, blk["ln2_g"], blk["ln2_b"])
    logits = xln @ blk["wr"]  # [B, L, E]
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # [B, L]
    alpha = jnp.take_along_axis(probs, idx[..., None], axis=-1)[..., 0]

    ex = blk["experts"]
    w1 = ex["w1"][idx]  # [B, L, D, F]
    b1 = ex["b1"][idx]
    w2 = ex["w2"][idx]
    b2 = ex["b2"][idx]
    h = jnp.maximum(jnp.einsum("bld,bldf->blf", xln, w1) + b1, 0.0)
    out = jnp.einsum("blf,blfd->bld", h, w2) + b2
    y = x + alpha[..., None] * out * mask[..., None]

    # Switch load-balance loss: E * sum_e f_e * P_e over real tokens.
    e = cfg.num_experts
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32) * mask[..., None]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    f_e = jnp.sum(onehot, axis=(0, 1)) / denom
    p_e = jnp.sum(probs * mask[..., None], axis=(0, 1)) / denom
    aux = e * jnp.sum(f_e * p_e)
    # router z-loss keeps logits bounded (Switch paper trick)
    zloss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return y, logits, idx, alpha, aux + cfg.router_z_loss * zloss


def forward(params: Params, ids, mask, cfg: ModelConfig):
    """Full training-path forward.

    Returns dict with lm_logits [B,L,V], cls_logits [B,C], per-MoE-layer
    router logits/idx/alpha, the embedding-layer output (hash-fn input),
    and the summed aux loss.
    """
    x = embed(params, ids)
    embedded = x
    router_logits, router_idx, router_alpha = [], [], []
    aux_total = 0.0
    for i, blk in enumerate(params["blocks"]):
        x = attention(blk, x, mask, cfg.n_heads)
        if i in cfg.moe_blocks:
            x, lg, idx, al, aux = moe_ffn_train(blk, x, mask, cfg)
            router_logits.append(lg)
            router_idx.append(idx)
            router_alpha.append(al)
            aux_total = aux_total + aux
        else:
            x = dense_ffn(blk, x)
    x = layer_norm(x, params["final_ln_g"], params["final_ln_b"])
    lm_logits = x @ params["lm_head"]["w"] + params["lm_head"]["b"]
    pooled = jnp.sum(x * mask[..., None], axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1, keepdims=True), 1.0
    )
    cls_logits = pooled @ params["cls_head"]["w"] + params["cls_head"]["b"]
    return {
        "lm_logits": lm_logits,
        "cls_logits": cls_logits,
        "router_logits": router_logits,
        "router_idx": router_idx,
        "router_alpha": router_alpha,
        "embedded": embedded,
        "aux": aux_total,
    }


def forward_forced_routing(params: Params, ids, mask, cfg: ModelConfig, forced_idx, forced_alpha):
    """Forward with router decisions *replaced* by external (hash) choices.

    forced_idx: i32 [M, B, L], forced_alpha: f32 [M, B, L].  This is the
    python-side twin of the Rust SiDA path, used for fidelity goldens
    (Tab 3/4): the router never runs; expert choice and alpha come from
    the hash function.
    """
    x = embed(params, ids)
    m = 0
    for i, blk in enumerate(params["blocks"]):
        x = attention(blk, x, mask, cfg.n_heads)
        if i in cfg.moe_blocks:
            xln = layer_norm(x, blk["ln2_g"], blk["ln2_b"])
            idx = forced_idx[m]
            alpha = forced_alpha[m]
            ex = blk["experts"]
            h = jnp.maximum(jnp.einsum("bld,bldf->blf", xln, ex["w1"][idx]) + ex["b1"][idx], 0.0)
            out = jnp.einsum("blf,blfd->bld", h, ex["w2"][idx]) + ex["b2"][idx]
            x = x + alpha[..., None] * out * mask[..., None]
            m += 1
        else:
            x = dense_ffn(blk, x)
    x = layer_norm(x, params["final_ln_g"], params["final_ln_b"])
    lm_logits = x @ params["lm_head"]["w"] + params["lm_head"]["b"]
    pooled = jnp.sum(x * mask[..., None], axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1, keepdims=True), 1.0
    )
    cls_logits = pooled @ params["cls_head"]["w"] + params["cls_head"]["b"]
    return {"lm_logits": lm_logits, "cls_logits": cls_logits}


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def lm_loss(lm_logits, ids, mask):
    """Causal next-token CE over real (non-pad) target positions."""
    logp = jax.nn.log_softmax(lm_logits[:, :-1], axis=-1)
    tgt = ids[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None].astype(jnp.int32), axis=-1)[..., 0]
    m = mask[:, 1:]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def cls_loss(cls_logits, labels):
    logp = jax.nn.log_softmax(cls_logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1))


def loss_fn(params: Params, ids, mask, labels, cfg: ModelConfig):
    out = forward(params, ids, mask, cfg)
    l_lm = lm_loss(out["lm_logits"], ids, mask)
    l_cls = cls_loss(out["cls_logits"], labels)
    total = l_lm + 0.5 * l_cls + cfg.aux_loss_coef * out["aux"]
    return total, {"lm": l_lm, "cls": l_cls, "aux": out["aux"]}


# --------------------------------------------------------------------------
# serving entry points (lowered to HLO by aot.py; weights are runtime args)
# --------------------------------------------------------------------------

def entry_embed(ids, tok, pos):
    """(i32 [1,L], [V,D], [L,D]) -> [1,L,D]."""
    return (jnp.take(tok, ids, axis=0) + pos[None, :, :],)


def make_entry_attn(cfg: ModelConfig):
    n_heads = cfg.n_heads

    def entry_attn(x, mask, ln_g, ln_b, wq, bq, wk, bk, wv, bv, wo, bo):
        blk = {
            "ln1_g": ln_g, "ln1_b": ln_b,
            "wq": wq, "bq": bq, "wk": wk, "bk": bk,
            "wv": wv, "bv": bv, "wo": wo, "bo": bo,
        }
        return (attention(blk, x, mask, n_heads),)

    return entry_attn


def entry_dense_ffn(x, ln_g, ln_b, w1, b1, w2, b2):
    """Dense FFN block via the Pallas expert kernel: [1,L,D] -> [1,L,D]."""
    from .kernels import expert_ffn

    bsz, L, d = x.shape
    xln = layer_norm(x, ln_g, ln_b).reshape(L, d)
    y = expert_ffn(xln, w1, b1, w2, b2, block_t=min(128, L))
    return (x + y.reshape(bsz, L, d),)


def entry_moe_ln(x, ln_g, ln_b):
    """The MoE block's pre-FFN layernorm, split out so the coordinator
    computes router/expert inputs exactly once: [1,L,D] -> [1,L,D]."""
    return (layer_norm(x, ln_g, ln_b),)


def entry_router(xln, wr):
    """Baseline router on the LN'd hidden states: [1,L,D],[D,E] ->
    (logits [1,L,E], idx i32 [1,L], alpha [1,L])."""
    from .kernels import router_top1

    bsz, L, d = xln.shape
    logits, idx, alpha = router_top1(xln.reshape(L, d), wr, block_t=min(128, L))
    return logits[None], idx[None], alpha[None]


def make_entry_expert(bucket: int):
    """Per-expert FFN on a padded token bucket: ([T,D], w1,b1,w2,b2) -> [T,D].

    T = bucket (static); the Rust coordinator packs the tokens routed to
    this expert into the smallest bucket >= count and zero-pads the rest.
    """
    from .kernels import expert_ffn

    def entry_expert(xtok, w1, b1, w2, b2):
        return (expert_ffn(xtok, w1, b1, w2, b2, block_t=min(128, bucket)),)

    return entry_expert


def entry_moe_combine(x, y, alpha, mask):
    """Residual combine after expert compute: x + alpha*y*mask.

    x, y: [1,L,D]; alpha, mask: [1,L]."""
    return (x + alpha[..., None] * y * mask[..., None],)


def entry_lm_head(x, ln_g, ln_b, w, b):
    xn = layer_norm(x, ln_g, ln_b)
    return (xn @ w + b,)


def entry_cls_head(x, mask, ln_g, ln_b, w, b):
    xn = layer_norm(x, ln_g, ln_b)
    pooled = jnp.sum(xn * mask[..., None], axis=1) / jnp.maximum(
        jnp.sum(mask, axis=1, keepdims=True), 1.0
    )
    return (pooled @ w + b,)


def entry_lm_nll(lm_logits, ids, mask):
    """Per-sentence summed NLL + token count (for rust-side perplexity)."""
    logp = jax.nn.log_softmax(lm_logits[:, :-1], axis=-1)
    tgt = ids[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None].astype(jnp.int32), axis=-1)[..., 0]
    m = mask[:, 1:]
    return jnp.sum(nll * m, axis=1), jnp.sum(m, axis=1)
