"""Model and dataset configuration registry for the SiDA-MoE reproduction.

The paper evaluates Switch-base-{8,64,128,256} (HF checkpoints) on
SST2 / MRPC (GLUE) and MultiRC (SuperGLUE).  This testbed has no GPU and
no checkpoints, so we build Switch-*style* models with the same expert
counts but tiny dense dims (see DESIGN.md §2), trained at build time on a
synthetic topic-clustered corpus.  Everything that matters to the serving
system — which experts fire, per-expert weight granularity, the
expert-dominated byte budget — is preserved.
"""

from dataclasses import dataclass, field, asdict
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """A Switch-style decoder-only LM with MoE FFN layers."""

    name: str
    vocab: int = 256
    d_model: int = 64
    d_ff: int = 128
    n_heads: int = 4
    n_blocks: int = 4
    # blocks whose FFN is a Switch MoE layer (every other block, per Switch)
    moe_blocks: Tuple[int, ...] = (1, 3)
    num_experts: int = 8
    n_classes: int = 4
    # router softmax temperature used at train time
    router_z_loss: float = 1e-3
    aux_loss_coef: float = 1e-2

    @property
    def num_moe_layers(self) -> int:
        return len(self.moe_blocks)

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def expert_param_count(self) -> int:
        """Parameters of a single expert MLP (w1, b1, w2, b2)."""
        return self.d_model * self.d_ff + self.d_ff + self.d_ff * self.d_model + self.d_model

    def moe_param_count(self) -> int:
        return self.num_moe_layers * self.num_experts * self.expert_param_count()

    def dense_param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        per_block_attn = 4 * (d * d + d) + 2 * d  # qkvo + ln
        per_block_ffn = d * f + f + f * d + d + 2 * d  # mlp + ln
        n_dense_ffn = self.n_blocks - self.num_moe_layers
        embed = v * d + MAX_SEQ_LEN * d
        head = 2 * d + d * v + v  # final ln + lm head
        cls = d * self.n_classes + self.n_classes
        # router weights live with the dense params (they are offloaded in SiDA)
        routers = self.num_moe_layers * (d * self.num_experts)
        return (
            self.n_blocks * per_block_attn
            + n_dense_ffn * per_block_ffn
            + self.num_moe_layers * 2 * d  # moe block ln
            + embed
            + head
            + cls
            + routers
        )

    def total_param_count(self) -> int:
        return self.moe_param_count() + self.dense_param_count()


@dataclass(frozen=True)
class HashFnConfig:
    """The SiDA hash function: FC compress -> 2-layer LSTM -> sparse
    attention (SparseMax) -> residual -> FC to per-MoE-layer expert logits.
    """

    hidden: int = 48
    n_lstm_layers: int = 2
    top_k: int = 4  # predicted experts exported per token per layer
    # truncated-KD truncation (paper uses T=30; capped at num_experts)
    kd_top_t: int = 30
    lambda_ce: float = 0.005  # paper: lambda = 0.005 weighting L_CE
    # NOTE(paper §3.5): objective is lambda*L_CE + L_TKD.  With
    # lambda=0.005 the CE term is tiny; we follow the paper's constants.


@dataclass(frozen=True)
class DatasetProfile:
    """Synthetic stand-in for a GLUE/SuperGLUE dataset: matched sentence
    length distribution + topic-clustered token statistics."""

    name: str
    seq_len: int  # padded model sequence length for this profile
    min_len: int
    max_len: int
    n_topics: int = 4
    # Zipf exponent of the per-topic token distribution
    zipf_a: float = 1.3
    # fraction of tokens drawn from the topic band vs the global tail
    topic_frac: float = 0.75


MAX_SEQ_LEN = 256

# --- registry -------------------------------------------------------------

MODEL_CONFIGS: Dict[str, ModelConfig] = {
    "switch8": ModelConfig(name="switch8", num_experts=8),
    "switch64": ModelConfig(name="switch64", num_experts=64),
    "switch128": ModelConfig(name="switch128", num_experts=128),
    "switch256": ModelConfig(name="switch256", num_experts=256),
}

# SST2: short sentences (paper Fig 2: mostly 5-30 tokens)
# MRPC: mid-length (paper: clustered 50-80)
# MultiRC: long paragraphs (paper: 200-500; we cap at 256 for CPU budget,
#          documented in DESIGN.md §2)
DATASET_PROFILES: Dict[str, DatasetProfile] = {
    "sst2": DatasetProfile(name="sst2", seq_len=32, min_len=5, max_len=30),
    "mrpc": DatasetProfile(name="mrpc", seq_len=96, min_len=40, max_len=90),
    "multirc": DatasetProfile(name="multirc", seq_len=256, min_len=150, max_len=250),
}

# token-count buckets for the per-expert FFN artifact (rust pads up)
EXPERT_TOKEN_BUCKETS: Tuple[int, ...] = (4, 16, 64, 256)

HASH_CONFIG = HashFnConfig()


def config_summary() -> List[dict]:
    rows = []
    for name, cfg in MODEL_CONFIGS.items():
        total = cfg.total_param_count()
        moe = cfg.moe_param_count()
        rows.append(
            {
                "name": name,
                "params": total,
                "moe_params": moe,
                "moe_frac": moe / total,
            }
        )
    return rows


if __name__ == "__main__":
    for row in config_summary():
        print(
            f"{row['name']:10s} total={row['params']/1e6:7.2f}M "
            f"moe={row['moe_params']/1e6:7.2f}M ({100*row['moe_frac']:5.1f}%)"
        )
