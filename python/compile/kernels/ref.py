"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest asserts each Pallas kernel
(interpret mode) against these under hypothesis-driven shape/seed sweeps,
and the L2 model uses them on the *training* path (fast on CPU) while the
AOT serving artifacts use the Pallas versions — so the oracle doubles as
the numerical contract between training and serving.
"""

import jax
import jax.numpy as jnp


def expert_ffn_ref(x, w1, b1, w2, b2):
    """One expert MLP: relu(x @ w1 + b1) @ w2 + b2.

    x: [T, D], w1: [D, F], b1: [F], w2: [F, D], b2: [D] -> [T, D]
    """
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2


def router_top1_ref(x, wr):
    """Switch router: logits, softmax probs, top-1 index and its alpha.

    x: [T, D], wr: [D, E] -> (logits [T,E], idx i32[T], alpha [T])
    """
    logits = x @ wr
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    alpha = jnp.take_along_axis(probs, idx[:, None], axis=-1)[:, 0]
    return logits, idx, alpha


@jax.custom_vjp
def sparsemax_ref(z):
    """SparseMax (Martins & Astudillo 2016): Euclidean projection of each
    row of z onto the probability simplex.  z: [..., L] -> [..., L].

    Closed form: sort descending, find the support size k(z), threshold
    tau, clamp.  The support set {j : 1 + j*z_(j) > cssv_j} is contiguous
    from j=1, so cssv_k = sum(z_sorted * cond) — no gather needed.

    A custom VJP supplies the analytic Jacobian (Martins & Astudillo
    Prop. 1: J = diag(s) - s s^T / |S| on the support S) — both because
    it is exact/cheap and because differentiating through jnp.sort hits a
    jaxlib operand_batching_dims limitation under vmap in this
    environment.
    """
    return _sparsemax_fwd_impl(z)


def _sparsemax_fwd_impl(z):
    z_sorted = jnp.sort(z, axis=-1)[..., ::-1]
    L = z.shape[-1]
    rng = jnp.arange(1, L + 1, dtype=z.dtype)
    cssv = jnp.cumsum(z_sorted, axis=-1)
    cond = (1.0 + rng * z_sorted > cssv).astype(z.dtype)
    k = jnp.sum(cond, axis=-1, keepdims=True)  # support size, >= 1
    cssv_k = jnp.sum(z_sorted * cond, axis=-1, keepdims=True)
    tau = (cssv_k - 1.0) / k
    return jnp.maximum(z - tau, 0.0)


def _sparsemax_fwd(z):
    p = _sparsemax_fwd_impl(z)
    return p, p


def _sparsemax_bwd(p, g):
    s = (p > 0.0).astype(g.dtype)  # support indicator
    k = jnp.maximum(jnp.sum(s, axis=-1, keepdims=True), 1.0)
    gs = jnp.sum(g * s, axis=-1, keepdims=True)
    return (s * (g - gs / k),)


sparsemax_ref.defvjp(_sparsemax_fwd, _sparsemax_bwd)


def sparse_attention_ref(h):
    """Self-attention over an LSTM output sequence with SparseMax weights.

    h: [L, H] (query = key = value = h, dot-product scores, paper §3.4.2)
    -> [L, H]
    """
    scores = h @ h.T / jnp.sqrt(jnp.asarray(h.shape[-1], h.dtype))
    w = sparsemax_ref(scores)
    return w @ h


def lstm_cell_ref(x, h, c, wx, wh, b):
    """Fused LSTM cell, gate order [i, f, g, o].

    x: [B, I], h,c: [B, H], wx: [I, 4H], wh: [H, 4H], b: [4H]
    -> (h', c')
    """
    gates = x @ wx + h @ wh + b
    H = h.shape[-1]
    i = jax.nn.sigmoid(gates[..., 0 * H : 1 * H])
    f = jax.nn.sigmoid(gates[..., 1 * H : 2 * H])
    g = jnp.tanh(gates[..., 2 * H : 3 * H])
    o = jax.nn.sigmoid(gates[..., 3 * H : 4 * H])
    c2 = f * c + i * g
    h2 = o * jnp.tanh(c2)
    return h2, c2
