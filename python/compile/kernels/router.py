"""L1 Pallas kernel: Switch router (logits + softmax + top-1 + alpha).

Used on the *baseline* serving paths (Standard / Reactive) where the true
router runs on-device; on the SiDA path routers never execute — the hash
table replaces them (paper §3.1: "all routers are offloaded to the main
memory and do not participate in the forward pass").

Grid is over token tiles; the [D, E] router matrix stays VMEM-resident
across steps (E <= 256, D <= 768 -> <= 0.4 MiB bf16).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _router_kernel(x_ref, wr_ref, logits_ref, idx_ref, alpha_ref):
    x = x_ref[...]
    logits = jnp.dot(x, wr_ref[...], preferred_element_type=jnp.float32)
    logits_ref[...] = logits
    # numerically-stable softmax over experts
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    idx_ref[...] = idx
    alpha_ref[...] = jnp.take_along_axis(probs, idx[:, None], axis=-1)[:, 0]


@functools.partial(jax.jit, static_argnames=("block_t",))
def router_top1(x, wr, *, block_t: int = 128):
    """x: [T, D], wr: [D, E] -> (logits [T,E] f32, idx [T] i32, alpha [T] f32)."""
    t, d = x.shape
    e = wr.shape[1]
    bt = min(block_t, t)
    assert t % bt == 0
    grid = (t // bt,)
    return pl.pallas_call(
        _router_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, e), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, e), lambda i: (i, 0)),
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, e), jnp.float32),
            jax.ShapeDtypeStruct((t,), jnp.int32),
            jax.ShapeDtypeStruct((t,), jnp.float32),
        ],
        interpret=True,
    )(x, wr)
