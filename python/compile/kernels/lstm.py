"""L1 Pallas kernel: fused LSTM cell for the SiDA hash function.

The hash function's backbone is a 2-layer LSTM (paper §3.4.2).  The cell
is the inner-loop hot spot: a [B, I]x[I, 4H] + [B, H]x[H, 4H] gate matmul
followed by the elementwise gate math.  Fusing all of it in one Pallas
block keeps the gate pre-activations in VMEM instead of materializing the
[B, 4H] tensor in HBM between matmul and nonlinearity.

The sequence loop lives at L2 (lax.scan in hashfn.py) so the scanned HLO
contains one fused cell per layer.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_cell_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h2_ref, c2_ref):
    x = x_ref[...]
    h = h_ref[...]
    c = c_ref[...]
    gates = (
        jnp.dot(x, wx_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, wh_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    H = h.shape[-1]
    i = jax.nn.sigmoid(gates[:, 0 * H : 1 * H])
    f = jax.nn.sigmoid(gates[:, 1 * H : 2 * H])
    g = jnp.tanh(gates[:, 2 * H : 3 * H])
    o = jax.nn.sigmoid(gates[:, 3 * H : 4 * H])
    c2 = f * c + i * g
    h2_ref[...] = o * jnp.tanh(c2)
    c2_ref[...] = c2


@jax.jit
def lstm_cell(x, h, c, wx, wh, b):
    """Fused LSTM cell.  x: [B, I], h/c: [B, H] -> (h', c')."""
    bsz, hidden = h.shape
    return pl.pallas_call(
        _lstm_cell_kernel,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hidden), jnp.float32),
            jax.ShapeDtypeStruct((bsz, hidden), jnp.float32),
        ],
        interpret=True,
    )(x, h, c, wx, wh, b)
