"""L1 Pallas kernel: single-expert FFN — the serving hot spot.

The paper's hot spot is the per-expert MLP invoked on the token slice the
router (or, in SiDA, the hash table) assigned to that expert.  The CUDA
implementation tiles this over threadblocks; the TPU adaptation (DESIGN.md
§Hardware-Adaptation) tiles over VMEM with BlockSpec:

  * grid = (T / BT,) over token tiles
  * each step stages an [BT, D] activation tile plus the full [D, F] /
    [F, D] weight tiles in VMEM and drives the MXU with two block matmuls
    fused around the ReLU — the HBM<->VMEM schedule the paper expressed
    with threadblocks is expressed here by the BlockSpec index maps.

VMEM budget (scaled-up config d=768, f=3072, bf16, BT=128):
  x tile 128x768 (0.19 MiB) + w1 768x3072 (4.5 MiB) + h 128x3072
  (0.75 MiB) + w2 3072x768 (4.5 MiB) + out (0.19 MiB) ~= 10.2 MiB < 16 MiB
  VMEM/core; with F-tiling (BF=1536) double-buffering also fits.
At the repro dims (64/128) everything fits in one tile trivially.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO (see /opt/xla-example).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_T = 128


def _expert_ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One token-tile step: o = relu(x @ w1 + b1) @ w2 + b2."""
    x = x_ref[...]
    # MXU-shaped block matmul; keep accumulation in f32.
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = jnp.maximum(h + b1_ref[...], 0.0)
    o = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = o + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_t",))
def expert_ffn(x, w1, b1, w2, b2, *, block_t: int = DEFAULT_BLOCK_T):
    """Pallas single-expert FFN.  x: [T, D] -> [T, D].

    T must be a multiple of the token tile (callers pad; the rust
    coordinator pads to the bucket sizes in configs.EXPERT_TOKEN_BUCKETS).
    """
    t, d = x.shape
    f = w1.shape[1]
    bt = min(block_t, t)
    assert t % bt == 0, f"token count {t} not a multiple of tile {bt}"
    grid = (t // bt,)
    return pl.pallas_call(
        _expert_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),  # activation tile
            pl.BlockSpec((d, f), lambda i: (0, 0)),  # w1 resident
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),  # w2 resident
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=True,
    )(x, w1, b1, w2, b2)
