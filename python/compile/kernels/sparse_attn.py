"""L1 Pallas kernel: sparse self-attention with SparseMax weights.

This is the attention layer of the SiDA hash function (paper §3.4.2):
Q = K = V = the LSTM output sequence; dot-product scores; SparseMax
instead of SoftMax so each position attends to the handful of critical
embeddings (the sparse cross-embedding dependency, c-hat in 1..4 per
paper Fig 6/7).

The whole [L, H] sequence fits VMEM at hash-function scale (L <= 256,
H <= 64 -> 64 KiB), so the kernel runs as a single fused block: scores,
simplex projection, and the weighted sum never round-trip to HBM.
SparseMax needs a descending sort along the key axis; in interpret mode
this lowers to XLA's sort HLO.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sparsemax(z):
    """Row-wise Euclidean projection onto the simplex (see ref.sparsemax_ref)."""
    L = z.shape[-1]
    z_sorted = jnp.sort(z, axis=-1)[..., ::-1]
    rng = jnp.arange(1, L + 1, dtype=z.dtype)
    cssv = jnp.cumsum(z_sorted, axis=-1)
    cond = (1.0 + rng * z_sorted > cssv).astype(z.dtype)
    k = jnp.sum(cond, axis=-1, keepdims=True)
    cssv_k = jnp.sum(z_sorted * cond, axis=-1, keepdims=True)
    tau = (cssv_k - 1.0) / k
    return jnp.maximum(z - tau, 0.0)


def _sparse_attn_kernel(h_ref, o_ref):
    h = h_ref[...]
    scale = jax.lax.rsqrt(jnp.asarray(h.shape[-1], h.dtype))
    scores = jnp.dot(h, h.T, preferred_element_type=jnp.float32) * scale
    w = _sparsemax(scores)
    o_ref[...] = jnp.dot(w, h, preferred_element_type=jnp.float32)


@jax.jit
def sparse_attention(h):
    """h: [L, H] -> [L, H] with SparseMax attention weights."""
    l, hd = h.shape
    return pl.pallas_call(
        _sparse_attn_kernel,
        out_shape=jax.ShapeDtypeStruct((l, hd), jnp.float32),
        interpret=True,
    )(h)
