"""Pallas kernels (L1) + pure-jnp oracles for the SiDA-MoE reproduction."""

from .moe import expert_ffn
from .router import router_top1
from .sparse_attn import sparse_attention
from .lstm import lstm_cell
from . import ref

__all__ = ["expert_ffn", "router_top1", "sparse_attention", "lstm_cell", "ref"]
