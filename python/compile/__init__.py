"""Build-time Python for the SiDA-MoE reproduction (L1 kernels + L2 model).

Everything in this package runs exactly once, at `make artifacts`:
training the tiny Switch models and hash functions, verifying kernels,
and lowering serving entry points to HLO text for the Rust coordinator.
Python is never on the request path.
"""
