"""Build-time training: tiny Switch LMs + SiDA hash functions.

Runs once under `make artifacts` (aot.py drives it).  Three stages per
model config, mirroring the paper's §4 setup:

  1. train the Switch model on the synthetic corpus mix (AdamW, causal LM
     + classifier + Switch load-balance aux loss);
  2. record the teacher data — router logits / top-1 ids per MoE layer —
     on the train split;
  3. train the hash function with lambda*L_CE + L_TKD(T) (paper §3.5) and
     evaluate the hash-hit rate on a held-out split (paper Tab 5).

No optax in this environment, so AdamW is implemented directly on the
PyTree.
"""

import functools
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import hashfn, model
from .configs import (
    DATASET_PROFILES,
    HASH_CONFIG,
    HashFnConfig,
    ModelConfig,
)
from .data import SyntheticCorpus


# --------------------------------------------------------------------------
# AdamW on a PyTree
# --------------------------------------------------------------------------

class AdamW:
    """Minimal AdamW (Loshchilov & Hutter 2019) over jax PyTrees."""

    def __init__(self, lr=5e-4, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01):
        self.lr, self.b1, self.b2, self.eps, self.wd = lr, b1, b2, eps, weight_decay

    def init(self, params):
        z = jax.tree_util.tree_map(jnp.zeros_like, params)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}

    def update(self, params, grads, state):
        t = state["t"] + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
        v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr, eps, wd = self.lr, self.eps, self.wd

        def step(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)

        new_params = jax.tree_util.tree_map(step, params, m, v)
        return new_params, {"m": m, "v": v, "t": t}


# --------------------------------------------------------------------------
# stage 1: train the Switch model
# --------------------------------------------------------------------------

def train_switch(
    cfg: ModelConfig,
    steps: int = 240,
    batch_size: int = 8,
    seed: int = 0,
    lr: float = 1e-3,
    log_every: int = 40,
) -> Tuple[Dict, List[Dict]]:
    """Train on the corpus mix.  Each profile keeps its own seq_len (jax
    re-jits once per shape — 3 shapes total — which is much cheaper on CPU
    than padding every batch to the longest profile)."""
    profiles = list(DATASET_PROFILES.values())
    corpora = [SyntheticCorpus(p, cfg.vocab, seed=seed) for p in profiles]
    params = model.init_params(cfg, seed=seed)
    opt = AdamW(lr=lr)
    opt_state = opt.init(params)

    @jax.jit
    def train_step(params, opt_state, ids, mask, labels):
        (loss, parts), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, ids, mask, labels, cfg
        )
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, loss, parts

    history = []
    t0 = time.time()
    gens = [c.batches(batch_size, steps, salt=7) for c in corpora]
    for step in range(steps):
        batch = next(gens[step % len(gens)])
        params, opt_state, loss, parts = train_step(
            params, opt_state, jnp.asarray(batch.ids), jnp.asarray(batch.mask),
            jnp.asarray(batch.labels)
        )
        if step % log_every == 0 or step == steps - 1:
            rec = {
                "step": step,
                "loss": float(loss),
                "lm": float(parts["lm"]),
                "cls": float(parts["cls"]),
                "aux": float(parts["aux"]),
                "wall_s": time.time() - t0,
            }
            history.append(rec)
            print(
                f"[{cfg.name}] step {step:4d} loss={rec['loss']:.4f} "
                f"lm={rec['lm']:.4f} cls={rec['cls']:.4f} aux={rec['aux']:.4f}"
            )
    return params, history


# --------------------------------------------------------------------------
# stage 2: teacher data for the hash function
# --------------------------------------------------------------------------

def collect_teacher(params, cfg: ModelConfig, profile_name: str, n_batches: int = 24,
                    batch_size: int = 8, seed: int = 0, salt: int = 77):
    """Run the trained model and record (embedded, router logits, top-1 ids,
    mask) — the hash function's training set (paper: 'pairs of input token
    embeddings and MoE expert activation patterns')."""
    profile = DATASET_PROFILES[profile_name]
    corpus = SyntheticCorpus(profile, cfg.vocab, seed=seed)
    fwd = jax.jit(functools.partial(model.forward, cfg=cfg))
    embs, logits, idxs, masks, ids_all, labels = [], [], [], [], [], []
    for batch in corpus.batches(batch_size, n_batches, salt=salt):
        out = fwd(params, jnp.asarray(batch.ids), jnp.asarray(batch.mask))
        embs.append(np.asarray(out["embedded"]))
        logits.append(np.stack([np.asarray(l) for l in out["router_logits"]], axis=2))
        idxs.append(np.stack([np.asarray(i) for i in out["router_idx"]], axis=2))
        masks.append(batch.mask)
        ids_all.append(batch.ids)
        labels.append(batch.labels)
    return {
        "embedded": np.concatenate(embs),  # [N, L, D]
        "teacher_logits": np.concatenate(logits),  # [N, L, M, E]
        "teacher_idx": np.concatenate(idxs),  # [N, L, M]
        "mask": np.concatenate(masks),  # [N, L]
        "ids": np.concatenate(ids_all),  # [N, L]
        "labels": np.concatenate(labels),  # [N]
    }


# --------------------------------------------------------------------------
# stage 3: train the hash function
# --------------------------------------------------------------------------

def train_hash(
    cfg: ModelConfig,
    teacher: Dict[str, np.ndarray],
    hcfg: HashFnConfig = HASH_CONFIG,
    steps: int = 300,
    batch_size: int = 16,
    seed: int = 1,
    lr: float = 3e-3,
    log_every: int = 50,
):
    hp = hashfn.init_hash_params(cfg, hcfg, seed=seed)
    opt = AdamW(lr=lr, weight_decay=1e-4)
    opt_state = opt.init(hp)
    n = teacher["embedded"].shape[0]

    @jax.jit
    def train_step(hp, opt_state, emb, tlg, tid, msk):
        (loss, parts), grads = jax.value_and_grad(hashfn.hash_loss, has_aux=True)(
            hp, emb, tlg, tid, msk, cfg, hcfg
        )
        hp, opt_state = opt.update(hp, grads, opt_state)
        return hp, opt_state, loss, parts

    rng = np.random.default_rng(seed)
    history = []
    for step in range(steps):
        sel = rng.choice(n, size=min(batch_size, n), replace=False)
        hp, opt_state, loss, parts = train_step(
            hp,
            opt_state,
            jnp.asarray(teacher["embedded"][sel]),
            jnp.asarray(teacher["teacher_logits"][sel]),
            jnp.asarray(teacher["teacher_idx"][sel]),
            jnp.asarray(teacher["mask"][sel]),
        )
        if step % log_every == 0 or step == steps - 1:
            rec = {"step": step, "loss": float(loss), "tkd": float(parts["tkd"]),
                   "ce": float(parts["ce"])}
            history.append(rec)
            print(f"[hash/{cfg.name}] step {step:4d} loss={rec['loss']:.4f} "
                  f"tkd={rec['tkd']:.4f} ce={rec['ce']:.4f}")
    return hp, history


def eval_hash(hp, cfg: ModelConfig, hcfg: HashFnConfig, teacher_eval) -> Dict[str, float]:
    """Held-out hash-hit rates (Tab 5 uses top-3; we also report top-1)."""
    s = hashfn.hash_forward(
        hp, jnp.asarray(teacher_eval["embedded"]), cfg, hcfg
    )
    tid = jnp.asarray(teacher_eval["teacher_idx"])
    msk = jnp.asarray(teacher_eval["mask"])
    return {
        "hits_top1": float(hashfn.hits_at_k(s, tid, msk, k=1)),
        "hits_top3": float(hashfn.hits_at_k(s, tid, msk, k=3)),
        f"hits_top{hcfg.top_k}": float(hashfn.hits_at_k(s, tid, msk, k=hcfg.top_k)),
    }


# --------------------------------------------------------------------------
# evaluation helpers used for goldens (Tab 3 / Tab 4 python twins)
# --------------------------------------------------------------------------

def eval_quality(params, hp, cfg: ModelConfig, hcfg: HashFnConfig, profile_name: str,
                 n_batches: int = 8, batch_size: int = 8, seed: int = 3, top_k_used: int = 1):
    """Perplexity + classification accuracy with (a) the true router and
    (b) hash-forced routing — the fidelity comparison of Tab 3/4."""
    profile = DATASET_PROFILES[profile_name]
    corpus = SyntheticCorpus(profile, cfg.vocab, seed=seed)
    fwd = jax.jit(functools.partial(model.forward, cfg=cfg))
    fwd_forced = jax.jit(functools.partial(model.forward_forced_routing, cfg=cfg))
    hfwd = jax.jit(functools.partial(hashfn.hash_forward, cfg=cfg, hcfg=hcfg))

    nll_r, nll_h, ntok = 0.0, 0.0, 0.0
    acc_r, acc_h, n = 0.0, 0.0, 0
    for batch in corpus.batches(batch_size, n_batches, salt=4242):
        ids = jnp.asarray(batch.ids)
        msk = jnp.asarray(batch.mask)
        out = fwd(params, ids, msk)
        emb = out["embedded"]
        logits = hfwd(hp, emb)  # [B,L,M,E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_idx = jax.lax.top_k(probs, top_k_used)
        # rust uses the hash's best expert and its (renormalized) alpha;
        # with top_k_used=1 alpha is the student's top prob
        f_idx = jnp.transpose(top_idx[..., 0], (2, 0, 1)).astype(jnp.int32)  # [M,B,L]
        f_alpha = jnp.transpose(top_p[..., 0], (2, 0, 1))
        out_h = fwd_forced(params, ids, msk, forced_idx=f_idx, forced_alpha=f_alpha)

        m = msk[:, 1:]

        def batch_nll(lm_logits):
            logp = jax.nn.log_softmax(lm_logits[:, :-1], axis=-1)
            tgt = ids[:, 1:]
            nl = -jnp.take_along_axis(logp, tgt[..., None].astype(jnp.int32), axis=-1)[..., 0]
            return float(jnp.sum(nl * m))

        nll_r += batch_nll(out["lm_logits"])
        nll_h += batch_nll(out_h["lm_logits"])
        ntok += float(jnp.sum(m))
        lbl = jnp.asarray(batch.labels)
        acc_r += float(jnp.sum(jnp.argmax(out["cls_logits"], -1) == lbl))
        acc_h += float(jnp.sum(jnp.argmax(out_h["cls_logits"], -1) == lbl))
        n += batch.ids.shape[0]

    return {
        "ppl_router": float(np.exp(nll_r / max(ntok, 1))),
        "ppl_hash": float(np.exp(nll_h / max(ntok, 1))),
        "acc_router": acc_r / max(n, 1),
        "acc_hash": acc_h / max(n, 1),
        "fidelity": (acc_h / max(n, 1)) / max(acc_r / max(n, 1), 1e-9),
    }
