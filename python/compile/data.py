"""Synthetic topic-clustered corpora standing in for SST2 / MRPC / MultiRC.

Why synthetic works here (DESIGN.md §2): the paper's serving results are
parameterized by (a) sentence length distribution and (b) data-dependent,
non-uniform expert activation.  A topic-clustered token model gives the
router clustered inputs to specialize on, so the trained Switch model
exhibits the same sentence-level activation sparsity the paper measures
(Fig 4), and the hash function has real structure to learn (Tab 5).

Token space layout (vocab=256 by default):
  0           PAD
  1           BOS
  2           EOS
  3..V-1      content tokens, carved into `n_topics` contiguous bands
Each sentence picks a topic; `topic_frac` of its tokens are Zipf-drawn
from the topic band, the rest from the global distribution.  The label of
a sentence is its topic id (classification task, Tab 4 stand-in).
"""

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np

from .configs import DatasetProfile

PAD, BOS, EOS = 0, 1, 2
CONTENT_START = 3


@dataclass
class Batch:
    ids: np.ndarray  # i32 [B, L]   padded token ids
    lengths: np.ndarray  # i32 [B]  true lengths incl BOS/EOS
    labels: np.ndarray  # i32 [B]   topic id
    mask: np.ndarray  # f32 [B, L]  1.0 on real tokens


class SyntheticCorpus:
    """Deterministic, seedable corpus generator for one dataset profile."""

    def __init__(self, profile: DatasetProfile, vocab: int, seed: int = 0):
        assert vocab > CONTENT_START + profile.n_topics
        self.profile = profile
        self.vocab = vocab
        self.seed = seed
        n_content = vocab - CONTENT_START
        self.band = n_content // profile.n_topics
        # per-topic Zipf weights over the band
        ranks = np.arange(1, self.band + 1, dtype=np.float64)
        w = ranks ** (-profile.zipf_a)
        self.topic_weights = w / w.sum()
        gw = np.arange(1, n_content + 1, dtype=np.float64) ** (-1.05)
        self.global_weights = gw / gw.sum()
        self.n_content = n_content

    def _rng(self, salt: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, hash(self.profile.name) & 0xFFFF, salt))

    def sample_sentence(self, rng: np.random.Generator) -> Tuple[np.ndarray, int, int]:
        p = self.profile
        topic = int(rng.integers(0, p.n_topics))
        length = int(rng.integers(p.min_len, p.max_len + 1))
        length = min(length, p.seq_len - 2)
        n_topic_tok = int(round(p.topic_frac * length))
        band_lo = CONTENT_START + topic * self.band
        topic_toks = band_lo + rng.choice(self.band, size=n_topic_tok, p=self.topic_weights)
        global_toks = CONTENT_START + rng.choice(
            self.n_content, size=length - n_topic_tok, p=self.global_weights
        )
        body = np.concatenate([topic_toks, global_toks])
        rng.shuffle(body)
        ids = np.full(p.seq_len, PAD, dtype=np.int32)
        ids[0] = BOS
        ids[1 : 1 + length] = body
        ids[1 + length] = EOS
        return ids, length + 2, topic

    def batches(self, batch_size: int, n_batches: int, salt: int = 0) -> Iterator[Batch]:
        rng = self._rng(salt)
        for _ in range(n_batches):
            ids = np.zeros((batch_size, self.profile.seq_len), dtype=np.int32)
            lengths = np.zeros(batch_size, dtype=np.int32)
            labels = np.zeros(batch_size, dtype=np.int32)
            for b in range(batch_size):
                ids[b], lengths[b], labels[b] = self.sample_sentence(rng)
            mask = (ids != PAD).astype(np.float32)
            yield Batch(ids=ids, lengths=lengths, labels=labels, mask=mask)

    def eval_batch(self, batch_size: int, salt: int = 10_000) -> Batch:
        return next(self.batches(batch_size, 1, salt=salt))


def mixed_corpus_batches(
    corpora, batch_size: int, n_batches: int, seed: int = 0
) -> Iterator[Batch]:
    """Round-robin over several profiles (the 'C4-like' pretraining mix).

    All profiles must share a seq_len for batching; callers pad externally
    if mixing profiles of different lengths.
    """
    iters = [c.batches(batch_size, n_batches, salt=1000 + i) for i, c in enumerate(corpora)]
    for j in range(n_batches):
        yield next(iters[j % len(iters)])
