"""AOT driver: train -> verify -> lower -> serialize.  Runs once at
`make artifacts`; the Rust coordinator is self-contained afterwards.

Per model config this emits into artifacts/<config>/:

  weights.bin + manifest.json   every tensor, experts individually
                                addressable (serialize.py)
  model.json                    topology descriptor for the Rust side
  <entry>_L{L}.hlo.txt          shape-specialized serving entry points,
                                one set per dataset profile seq-len
  expert_T{T}.hlo.txt           per-expert FFN for each token bucket
  golden.json                   numeric fixtures for Rust integration
                                tests (router decisions, hash tables,
                                logits slices, perplexities)
  hash_metrics.json             hash-hit rates + fidelity (Tab 4/5 twins)
  train_history.json            loss curves (EXPERIMENTS.md)

Interchange is HLO **text**: jax >= 0.5 serializes HloModuleProto with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import hashfn, model, serialize, train
from .configs import (
    DATASET_PROFILES,
    EXPERT_TOKEN_BUCKETS,
    HASH_CONFIG,
    MAX_SEQ_LEN,
    MODEL_CONFIGS,
    HashFnConfig,
    ModelConfig,
)


def hash_config_for(cfg: ModelConfig) -> HashFnConfig:
    """Scale the predictor with the expert count: a 48-wide LSTM is
    plenty for an 8-way routing problem but bottlenecks 128/256-way
    prediction (observed in Tab 5 hit rates)."""
    hidden = {8: 48, 64: 64, 128: 96, 256: 128}.get(cfg.num_experts, 96)
    return HashFnConfig(hidden=hidden)
from .data import SyntheticCorpus

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, arg_specs, path: str):
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# --------------------------------------------------------------------------
# entry-point lowering for one config
# --------------------------------------------------------------------------

def lower_all_entries(cfg: ModelConfig, outdir: str, verbose: bool = True,
                      hcfg: HashFnConfig = None):
    hcfg = hcfg or hash_config_for(cfg)
    d, f, v, e = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.num_experts
    h = hcfg.hidden
    m = cfg.num_moe_layers
    k = hcfg.top_k
    t0 = time.time()
    count = 0

    for prof in DATASET_PROFILES.values():
        L = prof.seq_len
        x = spec((1, L, d))
        msk = spec((1, L))
        entries = {
            f"embed_L{L}": (
                model.entry_embed,
                [spec((1, L), I32), spec((v, d)), spec((L, d))],
            ),
            f"attn_L{L}": (
                model.make_entry_attn(cfg),
                [x, msk] + [spec((d,)), spec((d,))]
                + [spec((d, d)), spec((d,))] * 4,
            ),
            f"dense_ffn_L{L}": (
                model.entry_dense_ffn,
                [x, spec((d,)), spec((d,)), spec((d, f)), spec((f,)),
                 spec((f, d)), spec((d,))],
            ),
            f"moe_ln_L{L}": (
                model.entry_moe_ln,
                [x, spec((d,)), spec((d,))],
            ),
            f"router_L{L}": (
                model.entry_router,
                [x, spec((d, e))],
            ),
            f"moe_combine_L{L}": (
                model.entry_moe_combine,
                [x, x, msk, msk],
            ),
            f"lm_head_L{L}": (
                model.entry_lm_head,
                [x, spec((d,)), spec((d,)), spec((d, v)), spec((v,))],
            ),
            f"cls_head_L{L}": (
                model.entry_cls_head,
                [x, msk, spec((d,)), spec((d,)), spec((d, cfg.n_classes)),
                 spec((cfg.n_classes,))],
            ),
            f"lm_nll_L{L}": (
                model.entry_lm_nll,
                [spec((1, L, v)), spec((1, L), I32), msk],
            ),
            f"hash_L{L}": (
                hashfn.make_entry_hash(cfg, hcfg),
                [spec((1, L), I32), spec((v, d)), spec((L, d)),
                 spec((d, h)), spec((h,)),
                 spec((h, 4 * h)), spec((h, 4 * h)), spec((4 * h,)),
                 spec((h, 4 * h)), spec((h, 4 * h)), spec((4 * h,)),
                 spec((h, m * e)), spec((m * e,))],
            ),
        }
        for name, (fn, specs) in entries.items():
            n = lower_entry(fn, specs, os.path.join(outdir, f"{name}.hlo.txt"))
            count += 1
            if verbose:
                print(f"  lowered {name} ({n/1024:.0f} KiB)")

    for bucket in EXPERT_TOKEN_BUCKETS:
        fn = model.make_entry_expert(bucket)
        specs = [spec((bucket, d)), spec((d, f)), spec((f,)), spec((f, d)), spec((d,))]
        n = lower_entry(fn, specs, os.path.join(outdir, f"expert_T{bucket}.hlo.txt"))
        count += 1
        if verbose:
            print(f"  lowered expert_T{bucket} ({n/1024:.0f} KiB)")
    print(f"[{cfg.name}] lowered {count} entries in {time.time()-t0:.1f}s")


# --------------------------------------------------------------------------
# goldens for Rust integration tests
# --------------------------------------------------------------------------

def build_goldens(cfg: ModelConfig, params, hp, hcfg, n_sent: int = 2) -> dict:
    golden = {"profiles": {}}
    fwd = jax.jit(functools.partial(model.forward, cfg=cfg))
    hfwd = jax.jit(functools.partial(
        hashfn.hash_forward, cfg=cfg, hcfg=hcfg))
    for prof in DATASET_PROFILES.values():
        corpus = SyntheticCorpus(prof, cfg.vocab, seed=5)
        batch = corpus.eval_batch(n_sent, salt=31337)
        ids = jnp.asarray(batch.ids)
        msk = jnp.asarray(batch.mask)
        out = fwd(params, ids, msk)
        logits = hfwd(hp, out["embedded"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_idx = jax.lax.top_k(probs, hcfg.top_k)
        lm = np.asarray(out["lm_logits"])
        nll = float(model.lm_loss(out["lm_logits"], ids, msk))
        golden["profiles"][prof.name] = {
            "ids": batch.ids.tolist(),
            "lengths": batch.lengths.tolist(),
            "labels": batch.labels.tolist(),
            "router_idx": np.stack(
                [np.asarray(i) for i in out["router_idx"]], axis=1).tolist(),  # [B,M,L]
            "router_alpha": np.round(np.stack(
                [np.asarray(a) for a in out["router_alpha"]], axis=1), 6).tolist(),
            "hash_top_idx": np.asarray(top_idx).tolist(),  # [B,L,M,K]
            "hash_top_alpha": np.round(np.asarray(top_p), 6).tolist(),
            "lm_logits_slice": np.round(lm[:, :4, :8], 4).tolist(),
            "lm_mean_nll": round(nll, 5),
            "cls_logits": np.round(np.asarray(out["cls_logits"]), 4).tolist(),
        }
    return golden


# --------------------------------------------------------------------------
# per-config build
# --------------------------------------------------------------------------

# Training schedule: larger expert counts need no more steps (per-token
# cost is E-independent on the gather path); teacher/hash set sizes are
# kept constant.
TRAIN_STEPS = {"switch8": 240, "switch64": 200, "switch128": 200, "switch256": 160}
HASH_STEPS = {"switch8": 420, "switch64": 600, "switch128": 1200, "switch256": 1200}
TEACHER_BATCHES = {"switch8": 16, "switch64": 24, "switch128": 32, "switch256": 32}


def build_config(name: str, outroot: str, force: bool = False, quick: bool = False):
    cfg = MODEL_CONFIGS[name]
    outdir = os.path.join(outroot, name)
    stamp = os.path.join(outdir, ".done")
    if os.path.exists(stamp) and not force:
        print(f"[{name}] up to date, skipping (use --force to rebuild)")
        return
    os.makedirs(outdir, exist_ok=True)
    t_start = time.time()
    hcfg = hash_config_for(cfg)

    steps = 30 if quick else TRAIN_STEPS[name]
    hsteps = 40 if quick else HASH_STEPS[name]
    bs = 4 if quick else 8

    # 1. train the switch model
    params, history = train.train_switch(cfg, steps=steps, batch_size=bs)

    # 2. teacher data on each profile, concatenated per-profile training
    teachers = {}
    for pname in DATASET_PROFILES:
        nb = 2 if quick else TEACHER_BATCHES[name]
        teachers[pname] = train.collect_teacher(
            params, cfg, pname, n_batches=nb, batch_size=4)

    # 3. hash function trained on the profile mix (paper trains one per
    #    dataset; the mix is one predictor evaluated per dataset — see
    #    DESIGN.md §2); per-profile shards keep their own seq_len.
    #    Two sweeps over the profiles so later shards don't dominate.
    #    Long profiles train with fewer, costlier steps.
    hp = None
    metrics = {"per_dataset": {}}
    share = {"sst2": 0.45, "mrpc": 0.33, "multirc": 0.22}
    for sweep in range(2):
        for rnd, pname in enumerate(DATASET_PROFILES):
            n_st = max(10, int(hsteps * share[pname] / 2))
            hp_new, _ = _train_hash_resume(cfg, teachers[pname], hp,
                                           steps=n_st, hcfg=hcfg,
                                           seed=1 + rnd + 10 * sweep)
            hp = hp_new

    # 4. evaluate hash-hit rate + fidelity per dataset (Tab 4/5 twins)
    for pname in DATASET_PROFILES:
        nb = 2 if quick else 6
        ev = train.collect_teacher(params, cfg, pname, n_batches=nb,
                                   batch_size=4, salt=999)
        metrics["per_dataset"][pname] = train.eval_hash(hp, cfg, hcfg, ev)
        top_k_used = 1 if pname == "sst2" else 3  # paper §4 hyperparams
        q = train.eval_quality(params, hp, cfg, hcfg, pname,
                               n_batches=2 if quick else 6, batch_size=4,
                               top_k_used=1)
        metrics["per_dataset"][pname].update(q)
        metrics["per_dataset"][pname]["top_k_used"] = top_k_used

    # 5. serialize weights (+ hash params)
    tensors = serialize.flatten_model_params(params) + serialize.flatten_hash_params(hp)
    manifest = serialize.write_weights(outdir, tensors)

    # 6. topology descriptor
    model_json = {
        "name": cfg.name,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "d_ff": cfg.d_ff,
        "n_heads": cfg.n_heads,
        "n_blocks": cfg.n_blocks,
        "moe_blocks": list(cfg.moe_blocks),
        "num_experts": cfg.num_experts,
        "n_classes": cfg.n_classes,
        "max_seq_len": MAX_SEQ_LEN,
        "hash": {
            "hidden": hcfg.hidden,
            "n_lstm_layers": hcfg.n_lstm_layers,
            "top_k": hcfg.top_k,
        },
        "profiles": {p.name: p.seq_len for p in DATASET_PROFILES.values()},
        "buckets": list(EXPERT_TOKEN_BUCKETS),
        "expert_param_bytes": cfg.expert_param_count() * 4,
        "moe_param_bytes": cfg.moe_param_count() * 4,
        "total_param_bytes": manifest["total_bytes"],
    }
    with open(os.path.join(outdir, "model.json"), "w") as fh:
        json.dump(model_json, fh, indent=1)

    # 7. goldens + metrics + history
    golden = build_goldens(cfg, params, hp, hcfg)
    with open(os.path.join(outdir, "golden.json"), "w") as fh:
        json.dump(golden, fh)
    with open(os.path.join(outdir, "hash_metrics.json"), "w") as fh:
        json.dump(metrics, fh, indent=1)
    with open(os.path.join(outdir, "train_history.json"), "w") as fh:
        json.dump(history, fh, indent=1)

    # 8. lower all serving entry points
    lower_all_entries(cfg, outdir, verbose=False, hcfg=hcfg)

    with open(stamp, "w") as fh:
        fh.write(f"built in {time.time()-t_start:.1f}s\n")
    print(f"[{name}] artifacts complete in {time.time()-t_start:.1f}s")


def _train_hash_resume(cfg, teacher, hp_init, steps, seed, hcfg=None):
    """train.train_hash but optionally resuming from existing params."""
    hcfg = hcfg or HASH_CONFIG
    if hp_init is None:
        return train.train_hash(cfg, teacher, hcfg=hcfg, steps=steps, seed=seed)
    opt = train.AdamW(lr=3e-3, weight_decay=1e-4)
    opt_state = opt.init(hp_init)
    n = teacher["embedded"].shape[0]

    @jax.jit
    def train_step(hp, opt_state, emb, tlg, tid, msk):
        (loss, parts), grads = jax.value_and_grad(hashfn.hash_loss, has_aux=True)(
            hp, emb, tlg, tid, msk, cfg, hcfg
        )
        hp, opt_state = opt.update(hp, grads, opt_state)
        return hp, opt_state, loss, parts

    rng = np.random.default_rng(seed)
    hp = hp_init
    hist = []
    for step in range(steps):
        sel = rng.choice(n, size=min(16, n), replace=False)
        hp, opt_state, loss, parts = train_step(
            hp, opt_state,
            jnp.asarray(teacher["embedded"][sel]),
            jnp.asarray(teacher["teacher_logits"][sel]),
            jnp.asarray(teacher["teacher_idx"][sel]),
            jnp.asarray(teacher["mask"][sel]),
        )
        if step == steps - 1:
            hist.append({"step": step, "loss": float(loss)})
            print(f"[hash/{cfg.name}] resume step {step} loss={float(loss):.4f}")
    return hp, hist


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root")
    ap.add_argument("--config", default="all",
                    help="model config name or 'all'")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="tiny training budget (CI smoke)")
    args = ap.parse_args()
    names = list(MODEL_CONFIGS) if args.config == "all" else [args.config]
    os.makedirs(args.out, exist_ok=True)
    for name in names:
        build_config(name, args.out, force=args.force, quick=args.quick)


if __name__ == "__main__":
    main()
