"""L2: the SiDA hash function (paper §3.4) + truncated KD loss (§3.5).

Architecture (paper §3.4.2, conditions (1)-(3)):
  embeddings [B,L,D]
    -> FC compress D->H                      (lightweight)
    -> 2-layer LSTM over L                   (sequential information)
    -> dot-product self-attention with
       **SparseMax** weights                 (sparse focus on the 1-4
                                              critical embeddings)
    -> residual add of the compressed
       current embedding                     (current token is always the
                                              most crucial, §3.4.2)
    -> FC to M*E logits per token            (one router head per MoE layer)

Training objective (paper §3.5): lambda * L_CE + L_TKD(T) — truncated KD
over the teacher router's top-T logits plus cross-entropy on the top-1
expert; lambda = 0.005, T = 30 (capped at E).

Like model.py, the training path uses ref-kernel math and the serving
entry (`make_entry_hash`) uses the Pallas kernels so they lower into the
AOT HLO.
"""

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import HashFnConfig, ModelConfig
from .kernels import ref

HashParams = Dict


def init_hash_params(cfg: ModelConfig, hcfg: HashFnConfig, seed: int = 1) -> HashParams:
    rng = np.random.default_rng(seed)
    d, h = cfg.d_model, hcfg.hidden
    m, e = cfg.num_moe_layers, cfg.num_experts

    def dense(shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
        return jnp.asarray(rng.normal(0.0, scale, size=shape), jnp.float32)

    def zeros(shape):
        return jnp.zeros(shape, jnp.float32)

    lstm_layers = []
    for i in range(hcfg.n_lstm_layers):
        in_dim = h
        lstm_layers.append(
            {
                "wx": dense((in_dim, 4 * h)),
                "wh": dense((h, 4 * h)),
                # forget-gate bias init at 1.0 helps tiny LSTMs converge
                "b": jnp.concatenate(
                    [zeros((h,)), jnp.ones((h,), jnp.float32), zeros((2 * h,))]
                ),
            }
        )
    return {
        "compress_w": dense((d, h)),
        "compress_b": zeros((h,)),
        "lstm": lstm_layers,
        "out_w": dense((h, m * e), scale=0.02),
        "out_b": zeros((m * e,)),
    }


def _lstm_layer(layer: HashParams, xs, cell_fn):
    """Run one LSTM layer over the sequence.  xs: [L, B, H] -> [L, B, H]."""
    bsz = xs.shape[1]
    hdim = layer["wh"].shape[0]
    h0 = jnp.zeros((bsz, hdim), jnp.float32)
    c0 = jnp.zeros((bsz, hdim), jnp.float32)

    def step(carry, x):
        h, c = carry
        h2, c2 = cell_fn(x, h, c, layer["wx"], layer["wh"], layer["b"])
        return (h2, c2), h2

    _, ys = jax.lax.scan(step, (h0, c0), xs)
    return ys


def hash_forward(hp: HashParams, embedded, cfg: ModelConfig, hcfg: HashFnConfig,
                 *, use_pallas: bool = False, pallas_lstm: bool = True):
    """embedded: [B, L, D] (token+pos embeddings) -> logits [B, L, M, E].

    `use_pallas` selects the Pallas kernels; `pallas_lstm=False` keeps
    the Pallas SparseMax attention but uses the fused-jnp LSTM cell.
    The serving entry uses that combination: an interpret-mode Pallas
    cell inside a `lax.scan` while-body lowers to dynamic-slice-heavy
    HLO that dominates the hash-build latency (EXPERIMENTS.md §Perf
    iteration 3); the jnp cell is numerically identical (pytest
    `test_pallas_path_matches_ref`).
    """
    if use_pallas:
        from .kernels import lstm_cell, sparse_attention

        cell_fn = lstm_cell if pallas_lstm else ref.lstm_cell_ref
        attn_fn = sparse_attention
    else:
        cell_fn, attn_fn = ref.lstm_cell_ref, ref.sparse_attention_ref

    bsz, L, d = embedded.shape
    m, e = cfg.num_moe_layers, cfg.num_experts
    z = embedded @ hp["compress_w"] + hp["compress_b"]  # [B, L, H]

    xs = jnp.transpose(z, (1, 0, 2))  # [L, B, H]
    for layer in hp["lstm"]:
        xs = _lstm_layer(layer, xs, cell_fn)
    hseq = jnp.transpose(xs, (1, 0, 2))  # [B, L, H]

    attended = jax.vmap(attn_fn)(hseq)  # SparseMax attention per sample
    r = attended + z  # residual: current embedding always matters (§3.4.2)
    logits = r @ hp["out_w"] + hp["out_b"]
    return logits.reshape(bsz, L, m, e)


# --------------------------------------------------------------------------
# truncated knowledge distillation (paper §3.5)
# --------------------------------------------------------------------------

def tkd_loss(student_logits, teacher_logits, mask, top_t: int):
    """KL(teacher_topT || student) restricted to the teacher's top-T experts.

    student/teacher logits: [B, L, M, E]; mask: [B, L].
    """
    e = teacher_logits.shape[-1]
    t = min(top_t, e)
    top_vals, top_idx = jax.lax.top_k(teacher_logits, t)  # [B,L,M,T]
    # teacher distribution renormalized over its top-T support
    t_logp = jax.nn.log_softmax(top_vals, axis=-1)
    s_sel = jnp.take_along_axis(student_logits, top_idx, axis=-1)
    # student log-prob over the same support (renormalized) — the paper's
    # truncation: the student only has to match where the teacher puts mass
    s_logp = jax.nn.log_softmax(s_sel, axis=-1)
    kl = jnp.sum(jnp.exp(t_logp) * (t_logp - s_logp), axis=-1)  # [B,L,M]
    w = mask[..., None]
    return jnp.sum(kl * w) / jnp.maximum(jnp.sum(w) * kl.shape[-1], 1.0)


def ce_loss(student_logits, teacher_idx, mask):
    """Cross-entropy on the teacher's top-1 expert.  teacher_idx: [B,L,M]."""
    logp = jax.nn.log_softmax(student_logits, axis=-1)
    nll = -jnp.take_along_axis(logp, teacher_idx[..., None].astype(jnp.int32), axis=-1)[..., 0]
    w = mask[..., None]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w) * nll.shape[-1], 1.0)


def hash_loss(hp, embedded, teacher_logits, teacher_idx, mask, cfg, hcfg):
    """Paper objective: lambda * L_CE + L_TKD(T)."""
    s = hash_forward(hp, embedded, cfg, hcfg)
    l_tkd = tkd_loss(s, teacher_logits, mask, hcfg.kd_top_t)
    l_ce = ce_loss(s, teacher_idx, mask)
    return hcfg.lambda_ce * l_ce + l_tkd, {"tkd": l_tkd, "ce": l_ce}


def hits_at_k(student_logits, teacher_idx, mask, k: int = 3) -> jnp.ndarray:
    """Hash-hit rate (paper Tab 5): is the teacher's top-1 expert inside
    the student's top-k prediction?"""
    _, pred = jax.lax.top_k(student_logits, k)  # [B,L,M,k]
    hit = jnp.any(pred == teacher_idx[..., None], axis=-1).astype(jnp.float32)
    w = mask[..., None]
    return jnp.sum(hit * w) / jnp.maximum(jnp.sum(w) * hit.shape[-1], 1.0)


# --------------------------------------------------------------------------
# serving entry point
# --------------------------------------------------------------------------

def make_entry_hash(cfg: ModelConfig, hcfg: HashFnConfig):
    """Hash-thread artifact: ids + embedding table + hash params ->
    (top-K expert ids i32 [1,L,M,K], alphas f32 [1,L,M,K]).

    Alphas are the student softmax probabilities of the predicted experts
    (the hash function approximates the router's scaling factor, §3.5);
    the Rust side renormalizes over the K it actually uses.
    """
    k = hcfg.top_k

    def entry_hash(ids, tok, pos, compress_w, compress_b,
                   l0_wx, l0_wh, l0_b, l1_wx, l1_wh, l1_b, out_w, out_b):
        hp = {
            "compress_w": compress_w,
            "compress_b": compress_b,
            "lstm": [
                {"wx": l0_wx, "wh": l0_wh, "b": l0_b},
                {"wx": l1_wx, "wh": l1_wh, "b": l1_b},
            ],
            "out_w": out_w,
            "out_b": out_b,
        }
        embedded = jnp.take(tok, ids, axis=0) + pos[None, :, :]
        logits = hash_forward(hp, embedded, cfg, hcfg, use_pallas=True,
                              pallas_lstm=False)
        probs = jax.nn.softmax(logits, axis=-1)
        # top-k via sort, not lax.top_k: the TopK HLO op ("largest=true")
        # postdates xla_extension 0.5.1's text parser (aot_recipe gotcha)
        neg = -probs
        top_idx = jnp.argsort(neg, axis=-1)[..., :k]
        top_p = -jnp.sort(neg, axis=-1)[..., :k]
        return top_idx.astype(jnp.int32), top_p

    return entry_hash
