"""Weight serialization: flat raw-f32/i32 blob + JSON manifest.

The Rust coordinator owns every tensor at serving time (experts must be
individually addressable so the memory manager can move them between
tiers), so the format is deliberately trivial to parse without external
crates: one little-endian binary blob and a JSON manifest of
{name, dtype, shape, offset, nbytes} records, 64-byte aligned.

Expert weights are stored **per expert** (`blocks.1.expert.17.w1`, ...):
the unit of offloading in SiDA is a single expert.
"""

import json
import os
from typing import Dict, List, Tuple

import numpy as np

ALIGN = 64


def flatten_model_params(params) -> List[Tuple[str, np.ndarray]]:
    out: List[Tuple[str, np.ndarray]] = []
    out.append(("embed.tok", np.asarray(params["embed"]["tok"])))
    out.append(("embed.pos", np.asarray(params["embed"]["pos"])))
    for i, blk in enumerate(params["blocks"]):
        p = f"blocks.{i}."
        for key in ("ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv",
                    "wo", "bo", "ln2_g", "ln2_b"):
            out.append((p + key, np.asarray(blk[key])))
        if "experts" in blk:
            out.append((p + "wr", np.asarray(blk["wr"])))
            ex = blk["experts"]
            n_exp = ex["w1"].shape[0]
            for e in range(n_exp):
                for key in ("w1", "b1", "w2", "b2"):
                    out.append((f"{p}expert.{e}.{key}", np.asarray(ex[key][e])))
        else:
            for key in ("w1", "b1", "w2", "b2"):
                out.append((p + key, np.asarray(blk[key])))
    out.append(("final_ln_g", np.asarray(params["final_ln_g"])))
    out.append(("final_ln_b", np.asarray(params["final_ln_b"])))
    out.append(("lm_head.w", np.asarray(params["lm_head"]["w"])))
    out.append(("lm_head.b", np.asarray(params["lm_head"]["b"])))
    out.append(("cls_head.w", np.asarray(params["cls_head"]["w"])))
    out.append(("cls_head.b", np.asarray(params["cls_head"]["b"])))
    return out


def flatten_hash_params(hp) -> List[Tuple[str, np.ndarray]]:
    out = [
        ("hash.compress_w", np.asarray(hp["compress_w"])),
        ("hash.compress_b", np.asarray(hp["compress_b"])),
    ]
    for i, layer in enumerate(hp["lstm"]):
        for key in ("wx", "wh", "b"):
            out.append((f"hash.lstm.{i}.{key}", np.asarray(layer[key])))
    out.append(("hash.out_w", np.asarray(hp["out_w"])))
    out.append(("hash.out_b", np.asarray(hp["out_b"])))
    return out


def write_weights(dirpath: str, tensors: List[Tuple[str, np.ndarray]]) -> dict:
    """Write weights.bin + manifest.json; returns the manifest dict."""
    os.makedirs(dirpath, exist_ok=True)
    records = []
    offset = 0
    blob = bytearray()
    for name, arr in tensors:
        if arr.dtype == np.float32:
            dtype = "f32"
        elif arr.dtype == np.int32:
            dtype = "i32"
        else:
            arr = arr.astype(np.float32)
            dtype = "f32"
        raw = np.ascontiguousarray(arr).tobytes()
        pad = (-offset) % ALIGN
        blob.extend(b"\0" * pad)
        offset += pad
        records.append(
            {
                "name": name,
                "dtype": dtype,
                "shape": list(arr.shape),
                "offset": offset,
                "nbytes": len(raw),
            }
        )
        blob.extend(raw)
        offset += len(raw)
    with open(os.path.join(dirpath, "weights.bin"), "wb") as f:
        f.write(bytes(blob))
    manifest = {"version": 1, "total_bytes": offset, "tensors": records}
    with open(os.path.join(dirpath, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest
