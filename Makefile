# SiDA-MoE build entry points.
#
#   make test       hermetic build + test (no artifacts needed)
#   make lint       clippy -D warnings + rustfmt check
#   make doc        rustdoc with warnings denied (doc rot fails here)
#   make artifacts  train the tiny models and export HLO + weights
#                   (requires the python/ JAX environment)
#   make bench      run every bench target (skips cleanly without
#                   artifacts / the pjrt feature)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: test lint fmt doc bench artifacts artifacts-quick clean

test:
	$(CARGO) build --release
	$(CARGO) test -q

lint:
	$(CARGO) clippy --all-targets -- -D warnings -A clippy::style -A clippy::complexity
	$(CARGO) fmt --check

doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

fmt:
	$(CARGO) fmt

bench:
	$(CARGO) bench

# Build-time training + AOT export (python/compile/aot.py). The serving
# stack never runs Python; these artifacts feed the opt-in golden layer
# (tests/golden.rs, --features pjrt).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts --config all

artifacts-quick:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts --config switch8 --quick

clean:
	$(CARGO) clean
